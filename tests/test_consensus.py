"""Consensus averaging: convergence, debiasing, Proposition 1 error bound."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.consensus import (DenseConsensus, consensus_schedule,
                                  debias_weights)
from repro.core.topology import (erdos_renyi, local_degree_weights, ring,
                                 spectral_gap, star)


def _blocks(n, d, r, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal((n, d, r)), jnp.float32)


def test_gossip_converges_to_mean():
    g = erdos_renyi(12, 0.4, seed=0)
    eng = DenseConsensus(g)
    z0 = _blocks(12, 8, 3)
    out = eng.run(z0, 400)
    mean = z0.mean(0)
    assert jnp.abs(out - mean[None]).max() < 1e-5


def test_debiased_run_approximates_sum():
    g = erdos_renyi(10, 0.5, seed=1)
    eng = DenseConsensus(g)
    z0 = _blocks(10, 6, 2)
    out = eng.run_debiased(z0, 300)
    total = z0.sum(0)
    assert jnp.abs(out - total[None]).max() < 1e-4


def test_debias_weights_definition():
    w = local_degree_weights(erdos_renyi(9, 0.4, seed=2))
    t_c = 7
    expected = np.linalg.matrix_power(w.T, t_c) @ np.eye(9)[0]
    assert np.allclose(debias_weights(w, t_c), expected)


def test_proposition1_geometric_decay():
    """Prop. 1: consensus error decays as delta ~ lambda_2(W)^{Tc} — i.e.
    log-linearly in T_c at the rate of the spectral contraction."""
    g = erdos_renyi(10, 0.5, seed=3)
    w = local_degree_weights(g)
    lam2 = 1.0 - spectral_gap(w)
    eng = DenseConsensus(g)
    z0 = _blocks(10, 12, 4, seed=5)
    z_sum = np.asarray(z0.sum(0))
    errs = {}
    for t_c in (10, 40):
        out = np.asarray(eng.run_debiased(z0, t_c))
        errs[t_c] = np.linalg.norm(out - z_sum[None], axis=(1, 2)).max()
    measured_rate = (errs[40] / errs[10]) ** (1 / 30)
    assert measured_rate <= lam2 * 1.1, (measured_rate, lam2)
    # and Prop. 1's absolute form with the contraction delta, modest constant
    z_abs = np.abs(np.asarray(z0)).sum(0)
    delta = 25 * lam2 ** 40
    assert errs[40] <= delta * np.linalg.norm(z_abs)


def test_consensus_error_decreases_with_tc():
    g = erdos_renyi(10, 0.3, seed=4)
    eng = DenseConsensus(g)
    z0 = _blocks(10, 10, 3, seed=6)
    z_sum = z0.sum(0)
    errs = []
    # t_c must exceed the graph diameter for the debias weight to be > 0
    for t_c in (8, 32, 128, 512):
        out = eng.run_debiased(z0, t_c)
        errs.append(float(jnp.abs(out - z_sum[None]).max()))
    assert errs == sorted(errs, reverse=True)
    assert errs[-1] < 1e-3


def test_star_consensus_works():
    eng = DenseConsensus(star(8))
    z0 = _blocks(8, 5, 2, seed=7)
    out = eng.run_debiased(z0, 200)
    assert jnp.abs(out - z0.sum(0)[None]).max() < 1e-3


def test_schedules():
    t_o = 10
    assert list(consensus_schedule("const", t_o, t_max=50)) == [50] * t_o
    lin1 = consensus_schedule("lin1", t_o)
    assert list(lin1) == [t + 1 for t in range(1, t_o + 1)]
    lin2 = consensus_schedule("lin2", t_o)
    assert list(lin2) == [2 * t + 1 for t in range(1, t_o + 1)]
    capped = consensus_schedule("lin5", t_o, cap=20)
    assert max(capped) == 20
    half = consensus_schedule("lin_half", 4)
    assert list(half) == [int(np.ceil(0.5 * t + 1)) for t in range(1, 5)]
    with pytest.raises(ValueError):
        consensus_schedule("nope", 5)


def test_ledger_counts_match_topology():
    from repro.core.metrics import CommLedger
    g = erdos_renyi(10, 0.4, seed=8)
    eng = DenseConsensus(g)
    z0 = _blocks(10, 6, 2)
    led = CommLedger()
    eng.run_debiased(z0, 13, led)
    # every round each directed edge carries one message
    assert led.p2p == 13 * g.adjacency.sum()
    assert led.scalars == led.p2p * 6 * 2
