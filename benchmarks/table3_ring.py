"""Table III / Fig. 3 — ring topology. The paper's point: a ring is (nearly)
periodic, mixes slowly, and S-DOT/SA-DOT converge poorly at practical T_c."""
from __future__ import annotations

from repro.core.consensus import DenseConsensus, consensus_schedule
from repro.core.sdot import sdot
from repro.core.topology import local_degree_weights, mixing_time, ring

from .common import Row, sample_problem, timed

N, R, T_O = 20, 5, 200


def run():
    rows = []
    covs, q_true = sample_problem(d=20, r=R, n_nodes=N, n_per=500, gap=0.7,
                                  seed=0)
    g = ring(N)
    eng = DenseConsensus(g)
    tau = mixing_time(local_degree_weights(g))
    for label, kind, cap in (("2t+1", "lin2", 50), ("50", "const", None),
                             ("min(5t+1,200)", "lin5", 200)):
        sched = consensus_schedule(kind, T_O, t_max=50, cap=cap)
        res, us = timed(sdot, covs=covs, engine=eng, r=R, t_outer=T_O,
                        schedule=sched, q_true=q_true)
        rows.append(Row(
            f"table3/ring/Tc={label}", us,
            {"p2p_k": round(res.ledger.per_node_p2p(N) / 1e3, 2),
             "tau_mix": tau,
             "final_err": f"{res.error_trace[-1]:.2e}"}))
    return rows
