"""Pure-jnp oracles for every Pallas kernel (ground truth for tests)."""
from __future__ import annotations

import jax.numpy as jnp

__all__ = ["gram_apply_ref", "batched_gram_apply_ref", "flash_attention_ref",
           "gram_qr_ref", "batched_slab_tq_ref", "batched_slab_apply_ref",
           "grid_block_tq_ref", "grid_block_apply_ref"]


def gram_apply_ref(x: jnp.ndarray, q: jnp.ndarray, normalize: bool = True) -> jnp.ndarray:
    """V = X (X^T Q) / n  — Step 5 of Alg. 1 without materializing M = XX^T.

    x: (d, n) local data block, q: (d, r) subspace iterate -> (d, r).
    """
    acc = jnp.promote_types(x.dtype, jnp.float32)
    s = x.astype(acc).T @ q.astype(acc)            # (n, r)
    v = x.astype(acc) @ s                          # (d, r)
    if normalize:
        v = v / x.shape[1]
    return v.astype(q.dtype)


def batched_gram_apply_ref(x_stack: jnp.ndarray, q_stack: jnp.ndarray,
                           n_true: jnp.ndarray) -> jnp.ndarray:
    """V[i] = X_i (X_i^T Q_i) / n_i over stacked nodes.

    x_stack: (N, d, n) zero-padded blocks (exact: padded columns are null in
    both matmuls), q_stack: (N, d, r), n_true: (N,) real per-node sample
    counts for the normalizer. One fused einsum pair — this is also the CPU
    execution path of ops.batched_gram_apply.
    """
    acc = jnp.promote_types(x_stack.dtype, jnp.float32)
    x32 = x_stack.astype(acc)
    s = jnp.einsum("idn,idr->inr", x32, q_stack.astype(acc))
    v = jnp.einsum("idn,inr->idr", x32, s)
    v = v / n_true.astype(acc)[:, None, None]
    return v.astype(q_stack.dtype)


def batched_slab_tq_ref(x_stack: jnp.ndarray, q_stack: jnp.ndarray) -> jnp.ndarray:
    """Z[i] = X_i^T Q_i over stacked feature slabs (F-DOT Alg. 2, step 1).

    x_stack: (N, d_max, n) zero-padded slabs, q_stack: (N, d_max, r) iterates
    padded with zero rows to match. Padding is exact: the padded rows are
    null in both operands, so they contribute nothing to the (n, r) product.
    """
    acc = jnp.promote_types(x_stack.dtype, jnp.float32)
    return jnp.einsum("idn,idr->inr", x_stack.astype(acc),
                      q_stack.astype(acc)).astype(q_stack.dtype)


def batched_slab_apply_ref(x_stack: jnp.ndarray, s_stack: jnp.ndarray) -> jnp.ndarray:
    """V[i] = X_i S_i over stacked feature slabs (F-DOT Alg. 2, step 3).

    x_stack: (N, d_max, n) zero-padded slabs, s_stack: (N, n, r) debiased
    consensus sums. Padded rows of X produce zero rows of V — exact.
    """
    acc = jnp.promote_types(x_stack.dtype, jnp.float32)
    return jnp.einsum("idn,inr->idr", x_stack.astype(acc),
                      s_stack.astype(acc)).astype(s_stack.dtype)


def grid_block_tq_ref(x_grid: jnp.ndarray, q_stack: jnp.ndarray) -> jnp.ndarray:
    """Z[i, j] = X_ij^T Q_i over an I x J grid of blocks (B-DOT stage 1).

    x_grid: (I, J, d_max, n_max) zero-padded blocks, q_stack: (I, d_max, r)
    zero-row-padded row iterates. Padded feature rows are null in both
    operands and padded sample columns of X produce zero rows of Z — exact.
    """
    acc = jnp.promote_types(x_grid.dtype, jnp.float32)
    return jnp.einsum("ijdn,idr->ijnr", x_grid.astype(acc),
                      q_stack.astype(acc)).astype(q_stack.dtype)


def grid_block_apply_ref(x_grid: jnp.ndarray, s_stack: jnp.ndarray) -> jnp.ndarray:
    """V[i, j] = X_ij S_j over an I x J grid of blocks (B-DOT stage 2).

    x_grid: (I, J, d_max, n_max) zero-padded blocks, s_stack: (J, n_max, r)
    per-column consensus sums. Padded sample columns of X multiply the padded
    (zero) rows of S and padded feature rows of X give zero rows of V — exact.
    """
    acc = jnp.promote_types(x_grid.dtype, jnp.float32)
    return jnp.einsum("ijdn,jnr->ijdr", x_grid.astype(acc),
                      s_stack.astype(acc)).astype(s_stack.dtype)


def flash_attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                        causal: bool = True, window: int | None = None,
                        scale: float | None = None) -> jnp.ndarray:
    """Standard softmax attention oracle.

    q: (b, h, sq, hd), k/v: (b, h, skv, hd). ``window``: optional sliding
    window (attend to keys within [i - window + 1, i]).
    """
    acc = jnp.float32
    hd = q.shape[-1]
    scale = (hd ** -0.5) if scale is None else scale
    logits = jnp.einsum("bhqd,bhkd->bhqk", q.astype(acc), k.astype(acc)) * scale
    sq, skv = q.shape[2], k.shape[2]
    qpos = jnp.arange(sq)[:, None] + (skv - sq)    # align ends (decode-friendly)
    kpos = jnp.arange(skv)[None, :]
    mask = jnp.ones((sq, skv), dtype=bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    logits = jnp.where(mask[None, None], logits, -jnp.inf)
    probs = jnp.exp(logits - logits.max(-1, keepdims=True))
    probs = probs / probs.sum(-1, keepdims=True)
    out = jnp.einsum("bhqk,bhkd->bhqd", probs, v.astype(acc))
    return out.astype(q.dtype)


def gram_qr_ref(v: jnp.ndarray) -> jnp.ndarray:
    """G = V^T V in f32 (oracle for the CholeskyQR Gram kernel)."""
    acc = jnp.promote_types(v.dtype, jnp.float32)
    v32 = v.astype(acc)
    return (v32.T @ v32).astype(jnp.float32)
