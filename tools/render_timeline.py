"""Render a plain-text Gantt + per-phase summary of a traced run.

Thin wrapper over the ``repro.obs`` CLI for the common post-mortem loop:
"show me WHAT every process was doing WHEN, then where the time went".

Usage:
    PYTHONPATH=src python tools/render_timeline.py <workdir-or-obs-dir> \
        [--width N] [--no-summary]

``<workdir-or-obs-dir>`` is a sweep/serving workdir (the journals live in
its ``obs/``) or an obs directory itself. Equivalent to running
``python -m repro.obs gantt`` followed by ``python -m repro.obs summary``.
"""
import argparse
import sys


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("dir", help="workdir (containing obs/) or obs dir")
    ap.add_argument("--width", type=int, default=72,
                    help="gantt columns (default 72)")
    ap.add_argument("--no-summary", action="store_true",
                    help="gantt only, skip the per-phase duration table")
    args = ap.parse_args(argv)

    from repro.obs.cli import render_gantt, render_summary, resolve_obs_dir

    obs_dir = resolve_obs_dir(args.dir)
    sys.stdout.write(render_gantt(obs_dir, width=args.width))
    if not args.no_summary:
        sys.stdout.write("\n")
        sys.stdout.write(render_summary(obs_dir))
    return 0


if __name__ == "__main__":
    sys.exit(main())
