"""Token-choice top-k MoE with shard-local dispatch.

Routing (argsort-based slot assignment) is performed PER DATA SHARD with a
local capacity: a global token sort is inherently unshardable, so dispatch
tensors would otherwise materialize at global-token size on every device
(measured 14 TB/device/step of all-reduce at kimi-k2 scale before this
design — EXPERIMENTS.md §Perf). With shard-local routing:

  * every sort / scatter / gather runs over the shard's own tokens,
  * the (n_shards, e, cap_loc, d) -> (e, n_shards*cap_loc, d) transpose is
    the canonical MoE all-to-all (token payloads move, weights stay),
  * capacity is enforced per (shard, expert) — standard local-capacity
    token-choice semantics; with n_shards=1 this is exactly the global
    behaviour.

Expert weights are stacked (E, d, f) and sharded over the "model" mesh axis.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..configs.base import ModelConfig, MoEConfig
from .layers import init_dense

__all__ = ["init_moe", "apply_moe", "moe_capacity"]


def moe_capacity(m: MoEConfig, n_tokens: int) -> int:
    cap = int(m.capacity_factor * m.top_k * n_tokens / m.n_experts)
    return max(8, -(-cap // 8) * 8)  # round up to 8


def init_moe(key, cfg: ModelConfig):
    m = cfg.moe
    d, f = cfg.d_model, m.d_expert
    dt = cfg.jnp_dtype
    ks = jax.random.split(key, 5)
    scale = d ** -0.5
    p = {
        "router": init_dense(ks[0], d, m.n_experts, jnp.float32),
        "w_gate": (jax.random.normal(ks[1], (m.n_experts, d, f)) * scale).astype(dt),
        "w_up": (jax.random.normal(ks[2], (m.n_experts, d, f)) * scale).astype(dt),
        "w_down": (jax.random.normal(ks[3], (m.n_experts, f, d)) * (f ** -0.5)).astype(dt),
    }
    if m.n_shared_experts:
        fs = m.d_expert * m.n_shared_experts
        kk = jax.random.split(ks[4], 3)
        p["shared"] = {
            "w_gate": init_dense(kk[0], d, fs, dt),
            "w_up": init_dense(kk[1], d, fs, dt),
            "w_down": init_dense(kk[2], fs, d, dt),
        }
    return p


def _route_shard(xf, router, m: MoEConfig, cap: int):
    """Slot assignment for ONE shard's tokens. xf: (t, d) -> dispatch plan."""
    t = xf.shape[0]
    k, e = m.top_k, m.n_experts
    logits = (xf @ router.astype(xf.dtype)).astype(jnp.float32)      # (t, e)
    gates, eidx = jax.lax.top_k(jax.nn.softmax(logits, axis=-1), k)  # (t, k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    flat_e = eidx.reshape(-1)                                # (t*k,)
    order = jnp.argsort(flat_e, stable=True)                 # group by expert
    sorted_e = flat_e[order]
    seg_start = jnp.searchsorted(sorted_e, sorted_e, side="left")
    pos_in_e = jnp.arange(t * k) - seg_start                 # rank within expert
    keep = pos_in_e < cap
    slot_sorted = sorted_e * cap + jnp.minimum(pos_in_e, cap - 1)
    inv = jnp.argsort(order, stable=True)
    return gates, keep[inv], jnp.where(keep, slot_sorted, e * cap)[inv]


def apply_moe(p, x, cfg: ModelConfig, act_specs=None):
    """x: (b, s, d) -> (b, s, d). act_specs["moe"] (optional) supplies the
    data-shard count and mesh axes for SPMD-friendly shard-local dispatch."""
    m = cfg.moe
    b, s, d = x.shape
    t = b * s
    k, e = m.top_k, m.n_experts
    spec = (act_specs or {}).get("moe") or {}
    n = spec.get("n_dp", 1)
    if t % n != 0:
        n = 1
    dp_ax, e_ax = spec.get("dp"), spec.get("e")
    t_loc = t // n
    cap = moe_capacity(m, t_loc)

    def pin(z, first_axes):
        if first_axes is None or not spec:
            return z
        return jax.lax.with_sharding_constraint(
            z, P(*([first_axes] + [None] * (z.ndim - 1))))

    xs = pin(x.reshape(n, t_loc, d), dp_ax)                 # (n, t_loc, d)

    gates, keep, slot = jax.vmap(
        lambda xf: _route_shard(xf, p["router"], m, cap))(xs)
    # (n, t_loc, k), (n, t_loc*k), (n, t_loc*k)

    tok_of = jnp.repeat(jnp.arange(t_loc), k)               # (t_loc*k,)

    def dispatch_shard(xf, keep_s, slot_s):
        contrib = jnp.where(keep_s[:, None], xf[tok_of], 0.0)
        return jnp.zeros((e * cap + 1, d), xf.dtype).at[slot_s].set(
            contrib, mode="drop")[:-1]

    buf = jax.vmap(dispatch_shard)(xs, keep, slot)          # (n, e*cap, d)
    buf = pin(buf, dp_ax)
    # ---- the MoE all-to-all: shard-major -> expert-major
    buf = buf.reshape(n, e, cap, d).transpose(1, 0, 2, 3).reshape(e, n * cap, d)
    buf = pin(buf, e_ax)

    # ---- expert FFNs: batched over the expert axis (sharded on "model")
    g = jnp.einsum("ecd,edf->ecf", buf, p["w_gate"])
    u = jnp.einsum("ecd,edf->ecf", buf, p["w_up"])
    yb = jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * u, p["w_down"])
    yb = pin(yb, e_ax)
    # ---- return all-to-all: expert-major -> shard-major
    yb = yb.reshape(e, n, cap, d).transpose(1, 0, 2, 3).reshape(n, e * cap, d)
    yb = pin(yb, dp_ax)

    def combine_shard(yb_s, keep_s, slot_s, gates_s):
        ytk = jnp.where(keep_s[:, None], yb_s[jnp.minimum(slot_s, e * cap - 1)],
                        0.0)
        return jnp.zeros((t_loc, d), yb_s.dtype).at[tok_of].add(
            ytk * gates_s.reshape(-1)[:, None].astype(yb_s.dtype))

    y = jax.vmap(combine_shard)(yb, keep, slot, gates)      # (n, t_loc, d)
    y = pin(y, dp_ax).reshape(t, d)

    if m.n_shared_experts:
        sp = p["shared"]
        xf = x.reshape(t, d)
        gs = xf @ sp["w_gate"]
        us = xf @ sp["w_up"]
        y = y + (jax.nn.silu(gs) * us) @ sp["w_down"]

    return y.reshape(b, s, d)
