"""Logical->mesh sharding rules (MaxText-style, path-based).

Axis conventions (DESIGN.md sec.5):
  * batch             -> all data-parallel axes ("pod","data") / ("data",)
  * TP (heads / ffn / vocab / experts) -> "model"
  * FSDP (ZeRO-3 weight shard)         -> "data"

A mesh axis is only assigned to a tensor dim when the dim size is divisible
by the axis size — otherwise the dim is replicated. This keeps the SPMD
partitioner out of uneven-padding corner cases; the roofline table shows
what replication costs where (and the hillclimb attacks the worst cells).
"""
from __future__ import annotations

from typing import Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..configs.base import ModelConfig

__all__ = ["dp_axes", "param_specs", "batch_specs", "decode_state_specs",
           "named", "constraint_spec"]


def dp_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def _axsize(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    return int(np.prod([mesh.shape[a] for a in axes]))


def _maybe(mesh: Mesh, dim: int, axes):
    """axes if dim divides evenly over them, else replicate."""
    return axes if dim % _axsize(mesh, axes) == 0 else None


def _leaf_spec(path: Tuple[str, ...], shape, mesh: Mesh, fsdp="data", tp="model"):
    names = [getattr(k, "key", getattr(k, "name", str(k))) for k in path]
    name = names[-1]
    in_groups = "groups" in names
    nd = len(shape)
    dims = list(shape)

    def spec(*entries):
        full = ([None] + list(entries)) if in_groups else list(entries)
        assert len(full) == nd, (names, shape, full)
        return P(*full)

    body = dims[1:] if in_groups else dims

    if name == "embed":
        # shard the MODEL dim, not vocab: the token gather then partitions as
        # a pass-through on the indexed dim (vocab-sharded gather trips XLA's
        # SPMD gather partitioner inside shard_map-auto regions).
        if nd == 3:  # audio: (K, V, D)
            return P(None, None, _maybe(mesh, dims[2], (fsdp, tp)))
        return P(None, _maybe(mesh, dims[1], (fsdp, tp)))
    if name == "lm_head":
        return P(_maybe(mesh, dims[0], fsdp), _maybe(mesh, dims[1], tp))
    if name in ("final_norm", "norm1", "norm2", "b_gates", "b_if", "lam",
                "bq", "bk", "bv", "conv_w"):
        return spec(*([None] * len(body)))
    if name == "router":  # (D, E)
        return spec(_maybe(mesh, body[0], fsdp), None)
    if name in ("w_q", "w_k", "w_v", "r_gates") and len(body) == 3:
        # block-diagonal per-head projections (h, hd, x): shard heads over TP
        return spec(_maybe(mesh, body[0], tp), None, None)
    if name in ("w_gate", "w_up") and len(body) == 3:     # moe experts (E, D, F)
        return spec(_maybe(mesh, body[0], tp), _maybe(mesh, body[1], fsdp), None)
    if name == "w_down" and len(body) == 3:               # moe (E, F, D)
        return spec(_maybe(mesh, body[0], tp), None, _maybe(mesh, body[2], fsdp))
    if name in ("wq", "wk", "wv", "w_up", "w_gate", "w_ffn_up", "w_gates",
                "r_gates", "w_in", "w_gate_in", "w_q", "w_k", "w_v",
                "w_rgate", "w_igate", "w_if"):            # (D_in, F_out)
        return spec(_maybe(mesh, body[0], fsdp), _maybe(mesh, body[1], tp))
    if name in ("wo", "w_down", "w_ffn_down", "w_out"):   # (F_in, D_out)
        return spec(_maybe(mesh, body[0], tp), _maybe(mesh, body[1], fsdp))
    # default: replicate
    return spec(*([None] * len(body)))


def param_specs(params, cfg: ModelConfig, mesh: Mesh):
    """PartitionSpec pytree matching the param pytree."""
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: _leaf_spec(path, leaf.shape, mesh), params)


def named(mesh: Mesh, specs):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))


def batch_specs(cfg: ModelConfig, mesh: Mesh, global_batch: int):
    """Specs for the input batch dict (tokens / labels / patch_embeds)."""
    dp = dp_axes(mesh)
    bax = dp if global_batch % _axsize(mesh, dp) == 0 else None
    toks = P(bax, None, None) if cfg.frontend == "audio_codec" else P(bax, None)
    out = {"tokens": toks, "labels": toks}
    if cfg.frontend == "vlm_patches":
        out["patch_embeds"] = P(bax, None, None)
    return out


def constraint_spec(cfg: ModelConfig, mesh: Mesh, global_batch: int) -> P:
    """Activation constraint (b, s, d) applied at block boundaries."""
    dp = dp_axes(mesh)
    bax = dp if global_batch % _axsize(mesh, dp) == 0 else None
    return P(bax, None, None)


def activation_specs(cfg: ModelConfig, mesh: Mesh, global_batch: int,
                     seq_len: int | None = None, dp=None):
    """Specs pinned onto intermediate activations (with_sharding_constraint).

    Without these, XLA's sharding propagation is free to reshard (b, s, d)
    activations over the model axis mid-layer, which costs a full all-gather
    per transition (measured: ~50x the collective bytes of the constrained
    program on qwen2-7b/train_4k — see EXPERIMENTS.md §Perf iteration 1).

    * act   — residual-stream (b, s, d): batch over the data axes, d
              replicated (Megatron TP keeps the stream replicated between
              the row/col-parallel matmul pairs).
    * seq   — batch-unshardable long-context decode: shard s over data.
    * logits— (b, s, V): vocab over the model axis when it divides.
    """
    dp = dp_axes(mesh) if dp is None else dp
    bax = dp if global_batch % _axsize(mesh, dp) == 0 else None
    act = P(bax, None, None)
    if bax is None and seq_len is not None and \
            seq_len % _axsize(mesh, dp) == 0:
        act = P(None, dp, None)            # sequence-parallel fallback
    vax = "model" if cfg.vocab_size % _axsize(mesh, "model") == 0 else None
    if cfg.frontend == "audio_codec":
        logits = P(bax, None, None, vax)
    else:
        logits = P(bax, None, vax)
    # attention internals (b, h, s, hd): shard heads over "model" only when
    # the head count divides — otherwise XLA invents expensive reshardings
    # (measured: a 30x collective-permute family on qwen2, EXPERIMENTS §Perf)
    tp = _axsize(mesh, "model")
    if cfg.n_heads % tp == 0:
        attn_q = P(bax, "model", None, None)
        attn_kv = P(bax, "model", None, None)  # post GQA expansion (h == n_heads)
    else:
        # indivisible head count: constraining would force replication ALs —
        # measured worse than letting the partitioner choose (§Perf, qwen2
        # iteration 2a, refuted). Leave attention internals unconstrained.
        attn_q = attn_kv = None
    moe = None
    if cfg.moe is not None:
        eax = "model" if cfg.moe.n_experts % tp == 0 else None
        # (data-shard axis, expert axis, #data shards) — apply_moe routes
        # per data shard (local capacity) so the token sort stays shardable;
        # see models/moe.apply_moe and EXPERIMENTS.md §Perf (kimi-k2).
        moe = {"dp": bax, "e": eax, "n_dp": _axsize(mesh, dp) if bax else 1}
    return {"act": act, "logits": logits, "attn_q": attn_q,
            "attn_kv": attn_kv, "moe": moe}


def decode_state_specs(state, cfg: ModelConfig, mesh: Mesh, global_batch: int):
    """Sharding for KV caches / recurrent states.

    Large batches shard over the data axes; batch=1 long-context decode
    shards the cache *length* over "data" instead (sequence-parallel decode —
    softmax stats are combined by the partitioner's all-reduce).
    """
    dp = dp_axes(mesh)
    big_batch = global_batch % _axsize(mesh, dp) == 0

    def leaf(path, x):
        names = [getattr(k, "key", getattr(k, "name", str(k))) for k in path]
        name = names[-1]
        nd = x.ndim
        if name == "index":
            return P()
        if name in ("k", "v", "k_scale", "v_scale"):   # (g, b, kv, S, hd|1)
            kv_ax = _maybe(mesh, x.shape[2], "model")
            # kv heads not divisible by the model axis (MHA archs like
            # musicgen, kv=1 GQA): shard the cache LENGTH over "model"
            # instead — attention partitions over keys with a partial-softmax
            # reduce, and the per-device cache capacity shrinks by the model
            # axis (38.7 GB -> 2.4 GB for musicgen decode_32k).
            s_model = _maybe(mesh, x.shape[3], "model") if kv_ax is None else None
            if big_batch:
                return P(None, dp, kv_ax, s_model, None)
            # batch=1 long-context: length takes every axis that divides
            s_axes = tuple(a for a in (list(dp) + ["model"])
                           if kv_ax is None or a != "model")
            return P(None, None, kv_ax, _maybe(mesh, x.shape[3], s_axes), None)
        if name == "c" and nd == 5:     # mlstm (g, b, h, hdk, hdv)
            return P(None, dp if big_batch else None,
                     _maybe(mesh, x.shape[2], "model"), None, None)
        if name == "n" and nd == 4:     # mlstm (g, b, h, hd)
            return P(None, dp if big_batch else None,
                     _maybe(mesh, x.shape[2], "model"), None)
        if nd == 3 and name in ("c", "n", "h"):   # slstm/rglru (g, b, d)
            return P(None, dp if big_batch else None,
                     _maybe(mesh, x.shape[2], "model"))
        if name == "conv":              # (g, b, 3, d)
            return P(None, dp if big_batch else None, None,
                     _maybe(mesh, x.shape[3], "model"))
        return P(*([None] * nd))

    return jax.tree_util.tree_map_with_path(leaf, state)
