"""Baselines from the paper's Figs. 4-6: sanity + the paper's ordering claims."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.baselines import d_pm, deepca, dpgd, dsa, seq_dist_pm, seq_pm
from repro.core.consensus import DenseConsensus
from repro.core.linalg import eigh_topr
from repro.core.sdot import sdot
from repro.core.topology import erdos_renyi
from repro.data.pipeline import gaussian_eigengap_data, partition_features


def test_seq_pm_converges(psa_problem):
    p = psa_problem
    q, errs = seq_pm(p["m"], p["r"], iters_per_vec=60, q_true=p["q_true"])
    assert errs[-1] < 1e-4
    # sequential plateau: early error (first vector converging) stays high
    assert errs[len(errs) // p["r"] - 1] > errs[-1] * 10


def test_seq_dist_pm_converges(psa_problem, er_engine):
    p = psa_problem
    q_nodes, errs = seq_dist_pm(p["covs"], er_engine, p["r"],
                                iters_per_vec=60, t_c=50, q_true=p["q_true"])
    assert errs[-1] < 1e-3


def test_dsa_reaches_neighborhood(psa_problem, er_engine):
    p = psa_problem
    q, errs = dsa(p["covs"], er_engine, p["r"], t_outer=300, lr=0.05,
                  q_true=p["q_true"])
    assert errs[-1] < 0.1
    assert errs[-1] < errs[0]


def test_dpgd_reaches_neighborhood(psa_problem, er_engine):
    p = psa_problem
    q, errs = dpgd(p["covs"], er_engine, p["r"], t_outer=300, lr=0.05,
                   q_true=p["q_true"])
    assert errs[-1] < 0.2
    assert errs[-1] < errs[0]


def test_deepca_converges(psa_problem, er_engine):
    p = psa_problem
    q, errs = deepca(p["covs"], er_engine, p["r"], t_outer=150, t_mix=3,
                     q_true=p["q_true"])
    assert errs[-1] < 1e-4


def test_sdot_beats_neighborhood_methods(psa_problem, er_engine):
    """Paper Fig. 4: S-DOT's floor is orders below DSA/DPGD's."""
    p = psa_problem
    res = sdot(covs=p["covs"], engine=er_engine, r=p["r"], t_outer=100,
               t_c=50, q_true=p["q_true"])
    _, e_dsa = dsa(p["covs"], er_engine, p["r"], t_outer=300, lr=0.05,
                   q_true=p["q_true"])
    _, e_dpgd = dpgd(p["covs"], er_engine, p["r"], t_outer=300, lr=0.05,
                     q_true=p["q_true"])
    assert res.error_trace[-1] < e_dsa[-1] / 100
    assert res.error_trace[-1] < e_dpgd[-1] / 100


def test_d_pm_feature_partitioned():
    d, r, n_nodes = 10, 3, 10
    x, c, _ = gaussian_eigengap_data(d, 2000, r, 0.5, seed=7)
    _, q_true = eigh_topr(x @ x.T, r)
    blocks = partition_features(x, n_nodes)
    eng = DenseConsensus(erdos_renyi(n_nodes, 0.5, seed=8))
    q, errs = d_pm(blocks, eng, r, iters_per_vec=80, t_c=60, q_true=q_true)
    assert errs[-1] < 1e-3
