"""S-DOT / SA-DOT: Theorem 1 behaviour — linear convergence to the true
subspace, consensus floors, equivalence with centralized OI under exact
consensus, and the paper's repeated-eigenvalue robustness claim."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.consensus import DenseConsensus, consensus_schedule
from repro.core.linalg import eigh_topr, orthonormal_init
from repro.core.metrics import subspace_error
from repro.core.oi import orthogonal_iteration
from repro.core.sdot import local_cov_apply, sadot, sdot
from repro.core.topology import complete, erdos_renyi
from repro.data.pipeline import gaussian_eigengap_data, partition_samples


def test_sdot_converges_to_global_eigenspace(psa_problem, er_engine):
    p = psa_problem
    res = sdot(covs=p["covs"], engine=er_engine, r=p["r"], t_outer=80, t_c=50,
               q_true=p["q_true"])
    assert res.error_trace[-1] < 1e-6
    # every node individually converged (consensus achieved)
    errs = [float(subspace_error(p["q_true"], res.q_nodes[i]))
            for i in range(p["n_nodes"])]
    assert max(errs) < 1e-5


def test_sdot_linear_rate(psa_problem, er_engine):
    """log(err) decreases ~linearly with slope <= 2 log(gap) until the floor."""
    p = psa_problem
    res = sdot(covs=p["covs"], engine=er_engine, r=p["r"], t_outer=40, t_c=50,
               q_true=p["q_true"])
    log_err = np.log(res.error_trace + 1e-300)
    head = log_err[2:14]  # pre-floor section
    slopes = np.diff(head)
    assert np.mean(slopes) < -0.2, "expected geometric decay"


def test_sdot_matches_centralized_oi_with_exact_consensus(psa_problem):
    """Complete graph + many consensus rounds == centralized OI per iterate."""
    p = psa_problem
    eng = DenseConsensus(complete(p["n_nodes"]))
    q0 = orthonormal_init(jax.random.PRNGKey(1), p["d"], p["r"])
    res = sdot(covs=p["covs"], engine=eng, r=p["r"], t_outer=10, t_c=200,
               q_init=q0)
    q_oi = orthogonal_iteration(p["m"], q0, 10)
    for i in range(p["n_nodes"]):
        assert float(subspace_error(q_oi, res.q_nodes[i])) < 1e-6  # fp32


def test_sdot_error_floor_ordering(psa_problem, er_engine):
    """Fewer consensus rounds -> higher error floor (inexact averaging)."""
    p = psa_problem
    floors = []
    for t_c in (3, 10, 50):
        res = sdot(covs=p["covs"], engine=er_engine, r=p["r"], t_outer=60,
                   t_c=t_c, q_true=p["q_true"])
        floors.append(res.error_trace[-1])
    assert floors[0] > floors[2]
    assert floors[2] < 1e-6


def test_sadot_matches_sdot_with_fewer_messages(psa_problem, er_engine):
    p = psa_problem
    s = sdot(covs=p["covs"], engine=er_engine, r=p["r"], t_outer=60, t_c=50,
             q_true=p["q_true"])
    # paper's SA-DOT schedules are implicitly capped at the experiment's
    # max consensus iterations (50) — verified against Table I P2P ratios
    a = sadot(covs=p["covs"], engine=er_engine, r=p["r"], t_outer=60,
              schedule_kind="lin2", cap=50, q_true=p["q_true"])
    assert a.error_trace[-1] < 5e-6
    assert a.ledger.p2p < s.ledger.p2p, "adaptive schedule must save messages"


def test_sadot_schedule_recorded(psa_problem, er_engine):
    p = psa_problem
    a = sadot(covs=p["covs"], engine=er_engine, r=p["r"], t_outer=10,
              schedule_kind="lin1")
    assert list(a.consensus_trace) == [t + 1 for t in range(1, 11)]


def test_sdot_gram_free_data_path_matches_cov_path(psa_problem, er_engine):
    p = psa_problem
    q0 = orthonormal_init(jax.random.PRNGKey(2), p["d"], p["r"])
    r1 = sdot(covs=p["covs"], engine=er_engine, r=p["r"], t_outer=15, t_c=50,
              q_init=q0, q_true=p["q_true"])
    r2 = sdot(data=p["blocks"], engine=er_engine, r=p["r"], t_outer=15, t_c=50,
              q_init=q0, q_true=p["q_true"])
    np.testing.assert_allclose(r1.error_trace, r2.error_trace, rtol=1e-3,
                               atol=1e-6)


def test_sdot_repeated_top_eigenvalues():
    """Paper Fig. 5: equal lambda_1..lambda_r is fine (only gap at r needed)."""
    d, r, n_nodes = 20, 4, 10
    x, c, _ = gaussian_eigengap_data(d, 5000, r, 0.5, seed=3, repeated_top=True)
    blocks = partition_samples(x, n_nodes)
    covs = jnp.stack([b @ b.T / b.shape[1] for b in blocks])
    _, q_true = eigh_topr(covs.sum(0), r)
    eng = DenseConsensus(erdos_renyi(n_nodes, 0.5, seed=4))
    res = sdot(covs=covs, engine=eng, r=r, t_outer=80, t_c=50, q_true=q_true)
    assert res.error_trace[-1] < 1e-6


def test_sdot_input_validation(psa_problem, er_engine):
    p = psa_problem
    with pytest.raises(ValueError):
        sdot(engine=er_engine, r=p["r"], t_outer=1)        # neither input
    with pytest.raises(ValueError):
        sdot(covs=p["covs"], data=p["blocks"], engine=er_engine, r=p["r"],
             t_outer=1)                                     # both inputs
    with pytest.raises(ValueError):
        sdot(covs=p["covs"][:3], engine=er_engine, r=p["r"], t_outer=1)


def test_local_cov_apply():
    covs = jnp.asarray(np.random.default_rng(0).standard_normal((4, 6, 6)),
                       jnp.float32)
    q = jnp.asarray(np.random.default_rng(1).standard_normal((4, 6, 2)),
                    jnp.float32)
    out = local_cov_apply(covs, q)
    for i in range(4):
        np.testing.assert_allclose(out[i], covs[i] @ q[i], rtol=1e-5)


def test_all_nodes_reach_consensus(psa_problem, er_engine):
    """After convergence the *projectors* agree across nodes (sign/rotation
    of Q may differ; span must not)."""
    p = psa_problem
    res = sdot(covs=p["covs"], engine=er_engine, r=p["r"], t_outer=60, t_c=50)
    q0 = res.q_nodes[0]
    for i in range(1, p["n_nodes"]):
        assert float(subspace_error(q0, res.q_nodes[i])) < 1e-5  # fp32
