# Intentionally empty: dryrun.py must set XLA_FLAGS before jax is imported,
# so nothing here may import jax (or submodules that do).
