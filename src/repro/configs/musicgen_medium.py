"""musicgen-medium — decoder-only over EnCodec tokens [arXiv:2306.05284; hf].

EnCodec frontend is a STUB: tokens arrive as (b, s, 4) codebook ids (delay
pattern applied upstream); embeddings are summed across codebooks and the
head emits 4 x 2048 logits per step.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium", family="audio",
    n_layers=48, d_model=1536, n_heads=24, n_kv_heads=24,
    d_ff=6144, vocab_size=2048,
    block_pattern=("attn",),
    frontend="audio_codec", n_codebooks=4,
)
