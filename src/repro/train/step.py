"""Train / serve step factories.

``make_train_step``      — standard SPMD step (pjit; XLA inserts the gradient
                           all-reduce over every data axis).
``make_psa_train_step``  — the paper-integrated step: gradients are reduced
                           *within* a pod by XLA (auto axes) but *across* pods
                           through PSA subspace compression (manual "pod"
                           axis inside shard_map). See optim/psa_compress.py.
``make_serve_step``      — one-token decode with KV/recurrent caches.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from ..configs.base import ModelConfig, PSAConfig
from ..core.compat import LEGACY_SHARD_MAP, shard_map
from ..models import sharding as shd
from ..models.transformer import decode_step, forward
from ..optim.adamw import AdamWConfig, adamw_init, adamw_update
from ..optim.psa_compress import compress_grads, psa_refresh

__all__ = ["loss_fn", "make_train_step", "make_psa_train_step", "make_serve_step"]


def loss_fn(params, batch: Dict[str, jnp.ndarray], cfg: ModelConfig, *,
            use_pallas: bool = False, remat: bool = True,
            unroll_layers: bool = False, act_specs=None) -> jnp.ndarray:
    """Mean next-token cross entropy (fp32 log-softmax; vocab may be sharded)."""
    logits = forward(params, batch, cfg, use_pallas=use_pallas, remat=remat,
                     unroll_layers=unroll_layers, act_specs=act_specs)
    logits = logits.astype(jnp.float32)
    labels = batch["labels"]
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    # gold-logit extraction as a masked sum: fuses into the reduction (no
    # (b,s,V) one-hot materialized) and partitions cleanly over sharded vocab
    vocab_iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape, logits.ndim - 1)
    gold = jnp.sum(jnp.where(vocab_iota == labels[..., None], logits, 0.0), axis=-1)
    return jnp.mean(logz - gold)


def _train_step(params, opt_state, batch, cfg: ModelConfig, opt: AdamWConfig,
                use_pallas: bool, remat: bool, act_specs=None):
    loss, grads = jax.value_and_grad(loss_fn)(
        params, batch, cfg, use_pallas=use_pallas, remat=remat,
        act_specs=act_specs)
    new_params, new_opt, gnorm = adamw_update(grads, opt_state, params, opt)
    metrics = {"loss": loss, "grad_norm": gnorm}
    return new_params, new_opt, metrics


def make_train_step(cfg: ModelConfig, mesh: Mesh, opt: AdamWConfig, *,
                    global_batch: int, use_pallas: bool = False,
                    remat: bool = True, donate: bool = True):
    """jit'd (params, opt_state, batch) -> (params, opt_state, metrics)."""
    aspecs = shd.activation_specs(cfg, mesh, global_batch)
    step = functools.partial(_train_step, cfg=cfg, opt=opt,
                             use_pallas=use_pallas, remat=remat,
                             act_specs=aspecs)
    # shardings: params/opt by rules; batch by batch_specs; metrics replicated
    bspecs = shd.batch_specs(cfg, mesh, global_batch)

    jit_kwargs = dict(donate_argnums=(0, 1) if donate else ())
    return jax.jit(step, **jit_kwargs), bspecs


def make_psa_train_step(cfg: ModelConfig, mesh: Mesh, opt: AdamWConfig,
                        psa: PSAConfig, *, global_batch: int,
                        use_pallas: bool = False, remat: bool = True):
    """Train step with PSA-compressed cross-pod gradient reduction.

    Per-pod gradients are computed inside shard_map with the "pod" axis
    MANUAL (each pod sees its own batch shard) and "data"/"model" AUTO (XLA
    keeps partitioning the model math). Cross-pod traffic is the projected
    U = P^T G plus the uncompressed small leaves — the paper's S-DOT
    consensus doing the reduction.

    The token-embedding GATHER (and its scatter VJP) runs OUTSIDE the
    manual region: XLA's SPMD partitioner cannot partition gathers inside a
    shard_map auto sub-mesh at production scale (CHECK-crash at 512 devices,
    iota device-group expansion). The inner region differentiates the model
    from the embeddings; the embedding-table gradient is assembled outside
    from the returned activation cotangent, where the pod axis is auto and
    the scatter partitions normally.
    """
    if "pod" not in mesh.axis_names:
        raise ValueError("PSA train step needs a multi-pod mesh ('pod' axis)")
    pod_axis = "pod"
    bspecs = shd.batch_specs(cfg, mesh, global_batch)
    n_pods = mesh.shape[pod_axis]
    # inside the shard_map body "pod" is manual — constraints may only name
    # the auto axes, and the batch is the per-pod shard
    # legacy shard_map: constraints naming auto axes inside the partial-auto
    # region CHECK-crash the old partitioner — drop the (perf-only) hints
    aspecs = None if LEGACY_SHARD_MAP else shd.activation_specs(
        cfg, mesh, max(global_batch // n_pods, 1), dp=("data",))
    from ..models.transformer import embed_inputs

    def local_loss(p, x, labels):
        batch = {"inputs_embeds": x, "labels": labels}
        # legacy shard_map also CHECK-crashes on lax.scan over a replicated
        # xs (the layer-group stack) with a pod-sharded carry inside the
        # partial-auto region — unroll the group loop there (same math)
        return loss_fn(p, batch, cfg, use_pallas=use_pallas, remat=remat,
                       unroll_layers=LEGACY_SHARD_MAP, act_specs=aspecs)

    def inner_grads(params, psa_state, x, labels):
        """shard_map body: per-pod grads -> PSA-reduced grads + x cotangent."""
        loss, (gp, gx) = jax.value_and_grad(local_loss, argnums=(0, 1))(
            params, x, labels)
        gp = dict(gp)
        g_emb_in = gp.pop("embed")      # zero unless embeddings are tied
        proj = {k: v for k, v in psa_state["proj"].items() if k != "embed"}
        ef = {k: v for k, v in psa_state["ef"].items() if k != "embed"}
        red, new_ef = compress_grads(gp, {"proj": proj, "ef": ef}, psa,
                                     pod_axis=pod_axis)
        if cfg.tie_embeddings:          # logits matmul contributes inside
            g_emb_in = (jax.lax.psum(g_emb_in.astype(jnp.float32), pod_axis)
                        / n_pods).astype(g_emb_in.dtype)
        red["embed"] = g_emb_in
        new_ef["embed"] = None
        loss = jax.lax.pmean(loss, pod_axis)
        return loss, red, new_ef, gx

    def inner_refresh(params, psa_state, x, labels):
        """shard_map body for the refresh pass: S-DOT subspace update from
        pod-local gradients, gossip over the pod ring inside the manual
        region (paper Alg. 1 with nodes == pods)."""
        grads = jax.grad(local_loss)(params, x, labels)
        return psa_refresh(grads, psa_state, psa, pod_axis=pod_axis)

    rep = P()
    batch_dims = 3 if cfg.frontend == "audio_codec" else 2
    lbl_spec = bspecs["labels"]
    lbl_pod = P(pod_axis, *lbl_spec[1:]) if lbl_spec[0] is not None else lbl_spec
    x_pod = P(pod_axis if lbl_spec[0] is not None else None, None, None)

    inner_sm = shard_map(
        inner_grads, mesh=mesh, axis_names={pod_axis}, check_vma=False,
        in_specs=(rep, rep, x_pod, lbl_pod),
        out_specs=(rep, rep, rep, x_pod))
    # refresh gossips with ppermute, which the legacy partial-auto partitioner
    # cannot lower (only psum survives there) — run the refresh body fully
    # manual on legacy jax: redundant compute over the auto axes, identical
    # math (refresh is rare: one S-DOT subspace update every refresh period)
    refresh_axes = set(mesh.axis_names) if LEGACY_SHARD_MAP else {pod_axis}
    refresh_sm = shard_map(
        inner_refresh, mesh=mesh, axis_names=refresh_axes, check_vma=False,
        in_specs=(rep, rep, x_pod, lbl_pod),
        out_specs=rep)

    def _embed_grad(params, batch, gx):
        """Embedding-table gradient via the gather VJP, in the AUTO region.

        gx is each pod's d(pod-mean loss)/dx; the global loss is the pod
        mean, so the table gradient is scatter(gx) / n_pods.
        """
        _, vjp = jax.vjp(lambda e: embed_inputs(
            {**params, "embed": e}, batch, cfg), params["embed"])
        (g_embed,) = vjp(gx.astype(params["embed"].dtype))
        return g_embed / n_pods

    def step(params, opt_state, psa_state, batch):
        x = embed_inputs(params, batch, cfg)          # gather: auto region
        loss, red, new_ef, gx = inner_sm(params, psa_state, x,
                                         batch["labels"])
        red = dict(red)
        red["embed"] = red["embed"] + _embed_grad(params, batch, gx)
        new_params, new_opt, gnorm = adamw_update(red, opt_state, params, opt)
        new_psa = {"proj": psa_state["proj"], "ef": new_ef}
        return new_params, new_opt, new_psa, {"loss": loss, "grad_norm": gnorm}

    def refresh(params, psa_state, batch):
        x = embed_inputs(params, batch, cfg)
        return refresh_sm(params, psa_state, x, batch["labels"])

    return jax.jit(step), jax.jit(refresh), bspecs


def make_serve_step(cfg: ModelConfig, mesh: Mesh, *, global_batch: int):
    """jit'd (params, state, tokens) -> (logits, state): one decode step."""

    def serve(params, state, tokens):
        return decode_step(params, state, tokens, cfg)

    return jax.jit(serve, donate_argnums=(1,))
