"""End-to-end behaviour: the paper's claims as executable assertions, and the
dry-run/roofline machinery on tiny inputs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.consensus import DenseConsensus
from repro.core.sdot import sadot, sdot
from repro.core.topology import erdos_renyi, ring, star
from repro.data.pipeline import gaussian_eigengap_data, partition_samples
from repro.core.linalg import eigh_topr


def _problem(gap, seed=0, n_nodes=10, d=20, r=5, n_per=500):
    x, _, _ = gaussian_eigengap_data(d, n_nodes * n_per, r, gap, seed=seed)
    blocks = partition_samples(x, n_nodes)
    covs = jnp.stack([b @ b.T / b.shape[1] for b in blocks])
    _, q_true = eigh_topr(covs.sum(0), r)
    return covs, q_true


def test_theorem1_rate_tracks_eigengap():
    """Smaller gap ratio (lambda_{r+1}/lambda_r) => faster convergence.
    The paper's rate is c * gap^t: err(t) for gap .3 << err(t) for gap .9."""
    eng = DenseConsensus(erdos_renyi(10, 0.5, seed=1))
    errs = {}
    for gap in (0.3, 0.9):
        covs, q_true = _problem(gap)
        res = sdot(covs=covs, engine=eng, r=5, t_outer=25, t_c=80,
                   q_true=q_true)
        errs[gap] = res.error_trace
    assert errs[0.3][10] < errs[0.9][10] / 10


def test_star_topology_converges_slower_than_er():
    """Paper Table IV narrative: star's central bottleneck slows consensus.
    With equal (small) T_c the star run has a worse error floor."""
    covs, q_true = _problem(0.7)
    r_er = sdot(covs=covs, engine=DenseConsensus(erdos_renyi(10, 0.5, seed=1)),
                r=5, t_outer=40, t_c=4, q_true=q_true)
    r_st = sdot(covs=covs, engine=DenseConsensus(star(10)),
                r=5, t_outer=40, t_c=4, q_true=q_true)
    assert r_er.error_trace[-1] < r_st.error_trace[-1]


def test_paper_communication_tradeoff():
    """Table I's shape: adaptive schedules cut P2P with no accuracy loss."""
    covs, q_true = _problem(0.7)
    eng = DenseConsensus(erdos_renyi(20, 0.25, seed=2), )
    covs20, q20 = _problem(0.7, n_nodes=20)
    s = sdot(covs=covs20, engine=eng, r=5, t_outer=50, t_c=50, q_true=q20)
    a = sadot(covs=covs20, engine=eng, r=5, t_outer=50,
              schedule_kind="lin_half", q_true=q20)
    assert a.ledger.p2p < 0.75 * s.ledger.p2p
    assert a.error_trace[-1] < 10 * max(s.error_trace[-1], 1e-9) + 1e-6


# ---------------------------------------------------------------------------
# launch layer on tiny inputs (no 512-device requirement)
# ---------------------------------------------------------------------------
def test_hlo_collective_parser():
    from repro.launch.hlo_analysis import collective_bytes
    hlo = """
  %ar = f32[128,256] all-reduce(f32[128,256] %x), replica_groups={{0,1,2,3}}
  %ag = bf16[64]{0} all-gather(bf16[16]{0} %y), replica_groups=[2,4]<=[8]
  %cp = f32[32,32] collective-permute(f32[32,32] %z)
"""
    st = collective_bytes(hlo, 8)
    assert st.count == {"all-reduce": 1, "all-gather": 1,
                        "collective-permute": 1}
    ar_wire = 128 * 256 * 4 * 2 * 3 / 4
    assert st.by_kind["all-reduce"] == pytest.approx(ar_wire)
    ag_wire = 64 * 2 * 3 / 4
    assert st.by_kind["all-gather"] == pytest.approx(ag_wire)
    assert st.by_kind["collective-permute"] == pytest.approx(32 * 32 * 4)


def test_roofline_terms_dominance():
    from repro.launch.hlo_analysis import roofline_terms
    from repro.launch.mesh import HW
    t = roofline_terms(flops_per_dev=197e12, bytes_per_dev=819e7,
                       wire_bytes_per_dev=50e7, hw=HW)
    assert t["dominant"] == "compute"
    assert t["t_compute_s"] == pytest.approx(1.0)
    t2 = roofline_terms(flops_per_dev=1, bytes_per_dev=819e9,
                        wire_bytes_per_dev=1, hw=HW)
    assert t2["dominant"] == "memory"


def test_model_flops_formula():
    from repro.configs import SHAPES, get_arch
    from repro.launch.dryrun import model_flops
    cfg = get_arch("qwen2-7b")
    n = cfg.param_count()
    assert model_flops(cfg, SHAPES["train_4k"]) == 6.0 * n * 4096 * 256
    assert model_flops(cfg, SHAPES["decode_32k"]) == 2.0 * n * 128
    moe = get_arch("kimi-k2-1t-a32b")
    assert model_flops(moe, SHAPES["train_4k"]) == \
        6.0 * moe.active_param_count() * 4096 * 256


def test_straggler_model():
    from repro.launch.analytic_cost import straggler_slowdown
    base = straggler_slowdown(n_nodes=10, t_step=0.01, delay=0.0)
    slow = straggler_slowdown(n_nodes=10, t_step=0.01, delay=0.01)
    assert slow / base >= 1.5
