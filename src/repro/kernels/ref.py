"""Pure-jnp oracles for every Pallas kernel (ground truth for tests)."""
from __future__ import annotations

import jax.numpy as jnp

__all__ = ["gram_apply_ref", "batched_gram_apply_ref", "flash_attention_ref",
           "gram_qr_ref", "batched_slab_tq_ref", "batched_slab_apply_ref",
           "grid_block_tq_ref", "grid_block_apply_ref", "ell_spmm_ref",
           "ell_spmm_scan_ref"]


def ell_spmm_ref(ell_idx: jnp.ndarray, ell_val: jnp.ndarray,
                 diag: jnp.ndarray, z_own: jnp.ndarray,
                 z_src: jnp.ndarray) -> jnp.ndarray:
    """out[i] = diag[i] z_own[i] + sum_l val[i,l] z_src[idx[i,l]], f32.

    The gather/einsum oracle for the ELL SpMM gossip round: one big (N, L,
    K) gather then a slot-contraction einsum. z_src may be a lower-
    precision (bf16) quantization of the payload — accumulation is f32
    either way. Padded slots carry weight 0, so no masking is needed.
    """
    msgs = jnp.take(z_src, ell_idx, axis=0).astype(jnp.float32)  # (N, L, K)
    acc = diag.astype(jnp.float32)[:, None] * z_own.astype(jnp.float32)
    return acc + jnp.einsum("nl,nlk->nk", ell_val.astype(jnp.float32), msgs)


def ell_spmm_dense_ref(ell_idx: jnp.ndarray, ell_val: jnp.ndarray,
                       diag: jnp.ndarray, z_own: jnp.ndarray,
                       z_src: jnp.ndarray) -> jnp.ndarray:
    """Densifying twin of ``ell_spmm_ref``: scatters the ELL slots back to
    an (N, N) off-diagonal matrix and uses the dense matmul. For hub-heavy
    graphs the padded width L approaches N and the gather path does nearly
    dense work with far worse constants than BLAS — past the measured CPU
    crossover (L ~ N/11) the O(N L) scatter + O(N^2 K) matmul is faster.
    Padded slots self-point with weight 0, so scatter-add is exact."""
    n = diag.shape[0]
    rows = jnp.broadcast_to(jnp.arange(n)[:, None], ell_idx.shape)
    w_off = jnp.zeros((n, n), jnp.float32).at[rows, ell_idx].add(
        ell_val.astype(jnp.float32))
    acc = diag.astype(jnp.float32)[:, None] * z_own.astype(jnp.float32)
    return acc + w_off @ z_src.astype(jnp.float32)


def ell_spmm_scan_ref(ell_idx: jnp.ndarray, ell_val: jnp.ndarray,
                      diag: jnp.ndarray, z_own: jnp.ndarray,
                      z_src: jnp.ndarray) -> jnp.ndarray:
    """Slot-at-a-time twin of ``ell_spmm_ref``: scans the L slot columns so
    peak memory stays O(N K) instead of O(N L K) — the fallback ops.py
    selects when the gathered message block would be large."""
    import jax

    acc0 = diag.astype(jnp.float32)[:, None] * z_own.astype(jnp.float32)

    def slot(acc, inp):
        cols, w = inp                                   # (N,), (N,)
        msgs = jnp.take(z_src, cols, axis=0).astype(jnp.float32)
        return acc + w.astype(jnp.float32)[:, None] * msgs, None

    acc, _ = jax.lax.scan(slot, acc0, (ell_idx.T, ell_val.T))
    return acc


def gram_apply_ref(x: jnp.ndarray, q: jnp.ndarray, normalize: bool = True) -> jnp.ndarray:
    """V = X (X^T Q) / n  — Step 5 of Alg. 1 without materializing M = XX^T.

    x: (d, n) local data block, q: (d, r) subspace iterate -> (d, r).
    """
    acc = jnp.promote_types(x.dtype, jnp.float32)
    s = x.astype(acc).T @ q.astype(acc)            # (n, r)
    v = x.astype(acc) @ s                          # (d, r)
    if normalize:
        v = v / x.shape[1]
    return v.astype(q.dtype)


def batched_gram_apply_ref(x_stack: jnp.ndarray, q_stack: jnp.ndarray,
                           n_true: jnp.ndarray) -> jnp.ndarray:
    """V[i] = X_i (X_i^T Q_i) / n_i over stacked nodes.

    x_stack: (N, d, n) zero-padded blocks (exact: padded columns are null in
    both matmuls), q_stack: (N, d, r), n_true: (N,) real per-node sample
    counts for the normalizer. One fused einsum pair — this is also the CPU
    execution path of ops.batched_gram_apply.
    """
    acc = jnp.promote_types(x_stack.dtype, jnp.float32)
    x32 = x_stack.astype(acc)
    s = jnp.einsum("idn,idr->inr", x32, q_stack.astype(acc))
    v = jnp.einsum("idn,inr->idr", x32, s)
    v = v / n_true.astype(acc)[:, None, None]
    return v.astype(q_stack.dtype)


def batched_slab_tq_ref(x_stack: jnp.ndarray, q_stack: jnp.ndarray) -> jnp.ndarray:
    """Z[i] = X_i^T Q_i over stacked feature slabs (F-DOT Alg. 2, step 1).

    x_stack: (N, d_max, n) zero-padded slabs, q_stack: (N, d_max, r) iterates
    padded with zero rows to match. Padding is exact: the padded rows are
    null in both operands, so they contribute nothing to the (n, r) product.
    """
    acc = jnp.promote_types(x_stack.dtype, jnp.float32)
    return jnp.einsum("idn,idr->inr", x_stack.astype(acc),
                      q_stack.astype(acc)).astype(q_stack.dtype)


def batched_slab_apply_ref(x_stack: jnp.ndarray, s_stack: jnp.ndarray) -> jnp.ndarray:
    """V[i] = X_i S_i over stacked feature slabs (F-DOT Alg. 2, step 3).

    x_stack: (N, d_max, n) zero-padded slabs, s_stack: (N, n, r) debiased
    consensus sums. Padded rows of X produce zero rows of V — exact.
    """
    acc = jnp.promote_types(x_stack.dtype, jnp.float32)
    return jnp.einsum("idn,inr->idr", x_stack.astype(acc),
                      s_stack.astype(acc)).astype(s_stack.dtype)


def grid_block_tq_ref(x_grid: jnp.ndarray, q_stack: jnp.ndarray) -> jnp.ndarray:
    """Z[i, j] = X_ij^T Q_i over an I x J grid of blocks (B-DOT stage 1).

    x_grid: (I, J, d_max, n_max) zero-padded blocks, q_stack: (I, d_max, r)
    zero-row-padded row iterates. Padded feature rows are null in both
    operands and padded sample columns of X produce zero rows of Z — exact.
    """
    acc = jnp.promote_types(x_grid.dtype, jnp.float32)
    return jnp.einsum("ijdn,idr->ijnr", x_grid.astype(acc),
                      q_stack.astype(acc)).astype(q_stack.dtype)


def grid_block_apply_ref(x_grid: jnp.ndarray, s_stack: jnp.ndarray) -> jnp.ndarray:
    """V[i, j] = X_ij S_j over an I x J grid of blocks (B-DOT stage 2).

    x_grid: (I, J, d_max, n_max) zero-padded blocks, s_stack: (J, n_max, r)
    per-column consensus sums. Padded sample columns of X multiply the padded
    (zero) rows of S and padded feature rows of X give zero rows of V — exact.
    """
    acc = jnp.promote_types(x_grid.dtype, jnp.float32)
    return jnp.einsum("ijdn,jnr->ijdr", x_grid.astype(acc),
                      s_stack.astype(acc)).astype(s_stack.dtype)


def flash_attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                        causal: bool = True, window: int | None = None,
                        scale: float | None = None) -> jnp.ndarray:
    """Standard softmax attention oracle.

    q: (b, h, sq, hd), k/v: (b, h, skv, hd). ``window``: optional sliding
    window (attend to keys within [i - window + 1, i]).
    """
    acc = jnp.float32
    hd = q.shape[-1]
    scale = (hd ** -0.5) if scale is None else scale
    logits = jnp.einsum("bhqd,bhkd->bhqk", q.astype(acc), k.astype(acc)) * scale
    sq, skv = q.shape[2], k.shape[2]
    qpos = jnp.arange(sq)[:, None] + (skv - sq)    # align ends (decode-friendly)
    kpos = jnp.arange(skv)[None, :]
    mask = jnp.ones((sq, skv), dtype=bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    logits = jnp.where(mask[None, None], logits, -jnp.inf)
    probs = jnp.exp(logits - logits.max(-1, keepdims=True))
    probs = probs / probs.sum(-1, keepdims=True)
    out = jnp.einsum("bhqk,bhkd->bhqd", probs, v.astype(acc))
    return out.astype(q.dtype)


def gram_qr_ref(v: jnp.ndarray) -> jnp.ndarray:
    """G = V^T V in f32 (oracle for the CholeskyQR Gram kernel)."""
    acc = jnp.promote_types(v.dtype, jnp.float32)
    v32 = v.astype(acc)
    return (v32.T @ v32).astype(jnp.float32)
