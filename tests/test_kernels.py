"""Pallas kernels vs pure-jnp oracles (interpret=True on CPU). Deterministic
cases only — the hypothesis shape/dtype sweeps live in
test_kernels_property.py so this module collects without hypothesis."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.gram_update import batched_gram_apply_pallas, \
    gram_apply_pallas


# ---------------------------------------------------------------------------
# gram_apply: V = X (X^T Q) / n
# ---------------------------------------------------------------------------
def test_gram_apply_padding_exact():
    """n not a multiple of block_n: zero-padding must not change the result."""
    x = jax.random.normal(jax.random.PRNGKey(0), (32, 513))
    q = jax.random.normal(jax.random.PRNGKey(1), (32, 8))
    out = ops.gram_apply(x, q, block_n=256, use_pallas=True)
    want = ref.gram_apply_ref(x, q)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=1e-4,
                               atol=1e-5)


def test_gram_apply_kernel_direct():
    """Direct pallas_call path (no wrapper) on an aligned shape."""
    x = jax.random.normal(jax.random.PRNGKey(2), (128, 1024))
    q = jax.random.normal(jax.random.PRNGKey(3), (128, 128))
    v = gram_apply_pallas(x, q, block_n=256, interpret=True)
    want = ref.gram_apply_ref(x, q, normalize=False)
    np.testing.assert_allclose(np.asarray(v), np.asarray(want, np.float32),
                               rtol=1e-4, atol=1e-3)


def test_gram_apply_equals_explicit_covariance():
    """The kernel IS Step 5 of Alg. 1: X(X^T Q)/n == (XX^T/n) Q."""
    x = jax.random.normal(jax.random.PRNGKey(4), (24, 512))
    q = jax.random.normal(jax.random.PRNGKey(5), (24, 4))
    m = x @ x.T / x.shape[1]
    out = ops.gram_apply(x, q, use_pallas=True, block_n=256)
    np.testing.assert_allclose(np.asarray(out), np.asarray(m @ q), rtol=1e-4,
                               atol=1e-5)


# ---------------------------------------------------------------------------
# batched gram_apply: V[i] = X_i (X_i^T Q_i) / n_i, (node, col-block) grid
# ---------------------------------------------------------------------------
def test_batched_gram_apply_kernel_direct():
    """Direct pallas_call on aligned shapes: per-node results independent."""
    n_nodes, d, n, r = 3, 64, 512, 8
    key = jax.random.PRNGKey(11)
    kx, kq = jax.random.split(key)
    x = jax.random.normal(kx, (n_nodes, d, n))
    q = jax.random.normal(kq, (n_nodes, d, r))
    v = batched_gram_apply_pallas(x, q, block_n=256, interpret=True)
    for i in range(n_nodes):
        want = ref.gram_apply_ref(x[i], q[i], normalize=False)
        np.testing.assert_allclose(np.asarray(v[i]),
                                   np.asarray(want, np.float32),
                                   rtol=1e-4, atol=1e-3)


def test_batched_gram_apply_ragged_padding_exact():
    """Ragged n_i via zero padding must equal the per-node unpadded oracle."""
    rng = np.random.default_rng(0)
    n_true = np.array([300, 150, 512, 77])
    n_nodes, d, r = len(n_true), 32, 5
    n_max = int(n_true.max())
    x_stack = np.zeros((n_nodes, d, n_max), np.float32)
    for i, ni in enumerate(n_true):
        x_stack[i, :, :ni] = rng.standard_normal((d, ni))
    q = jnp.asarray(rng.standard_normal((n_nodes, d, r)), jnp.float32)
    out = ops.batched_gram_apply(jnp.asarray(x_stack), q,
                                 jnp.asarray(n_true, jnp.float32),
                                 block_n=256, use_pallas=True, interpret=True)
    for i, ni in enumerate(n_true):
        want = ref.gram_apply_ref(jnp.asarray(x_stack[i, :, :ni]), q[i])
        np.testing.assert_allclose(np.asarray(out[i]), np.asarray(want),
                                   rtol=2e-4, atol=2e-4)


def test_batched_gram_apply_ref_fallback_matches_kernel():
    """CPU auto-dispatch (oracle) == explicit interpret-mode kernel."""
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.standard_normal((2, 16, 256)), jnp.float32)
    q = jnp.asarray(rng.standard_normal((2, 16, 4)), jnp.float32)
    n_true = jnp.asarray([256.0, 200.0], jnp.float32)
    a = ops.batched_gram_apply(x, q, n_true, use_pallas=False)
    b = ops.batched_gram_apply(x, q, n_true, block_n=128, use_pallas=True,
                               interpret=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4,
                               atol=1e-5)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("window", [32, 64, 128])
def test_flash_attention_sliding_window(window):
    q = jax.random.normal(jax.random.PRNGKey(0), (1, 2, 256, 32))
    k = jax.random.normal(jax.random.PRNGKey(1), (1, 2, 256, 32))
    v = jax.random.normal(jax.random.PRNGKey(2), (1, 2, 256, 32))
    out = ops.flash_attention(q, k, v, causal=True, window=window,
                              use_pallas=True)
    want = ref.flash_attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=2e-5,
                               atol=2e-5)


def test_flash_attention_cross_lengths():
    """Decode-style: sq < skv, positions aligned at the end."""
    q = jax.random.normal(jax.random.PRNGKey(3), (1, 2, 128, 32))
    k = jax.random.normal(jax.random.PRNGKey(4), (1, 2, 384, 32))
    v = jax.random.normal(jax.random.PRNGKey(5), (1, 2, 384, 32))
    out = ops.flash_attention(q, k, v, causal=True, use_pallas=True)
    want = ref.flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=2e-5,
                               atol=2e-5)


def test_flash_attention_small_falls_back():
    """Below one block the wrapper must use the oracle (still correct)."""
    q = jax.random.normal(jax.random.PRNGKey(6), (1, 2, 17, 16))
    k = jax.random.normal(jax.random.PRNGKey(7), (1, 2, 17, 16))
    v = jax.random.normal(jax.random.PRNGKey(8), (1, 2, 17, 16))
    out = ops.flash_attention(q, k, v, causal=True, use_pallas=True)
    want = ref.flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=1e-5,
                               atol=1e-5)


def test_flash_attention_rows_sum_to_one_property():
    """Output of attention over constant V equals that constant (softmax
    weights sum to 1 — catches masking/normalization bugs)."""
    q = jax.random.normal(jax.random.PRNGKey(9), (1, 2, 256, 32))
    k = jax.random.normal(jax.random.PRNGKey(10), (1, 2, 256, 32))
    v = jnp.ones((1, 2, 256, 32))
    out = ops.flash_attention(q, k, v, causal=True, use_pallas=True)
    np.testing.assert_allclose(np.asarray(out), 1.0, rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# gram_qr: G = V^T V (CholeskyQR hot matmul)
# ---------------------------------------------------------------------------
def test_gram_qr_matches_ref_aligned():
    v = jax.random.normal(jax.random.PRNGKey(12), (1536, 8))
    out = ops.gram_qr(v, block_d=512, use_pallas=True)
    want = ref.gram_qr_ref(v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-4, atol=1e-3)


def test_gram_qr_symmetric_psd():
    v = jax.random.normal(jax.random.PRNGKey(1), (2048, 16))
    g = np.asarray(ops.gram_qr(v, use_pallas=True))
    np.testing.assert_allclose(g, g.T, rtol=1e-6)
    assert np.linalg.eigvalsh(g).min() > -1e-3


# ---------------------------------------------------------------------------
# slab ops: Z[i] = X_i^T Q_i and V[i] = X_i S_i (fused F-DOT hot matmuls)
# ---------------------------------------------------------------------------
def test_batched_slab_tq_matches_ref():
    """(node, sample-block) kernel vs fused-einsum oracle, unaligned n."""
    key = jax.random.PRNGKey(21)
    kx, kq = jax.random.split(key)
    x = jax.random.normal(kx, (4, 8, 700))
    q = jax.random.normal(kq, (4, 8, 5))
    out = ops.batched_slab_tq(x, q, block_n=256, use_pallas=True,
                              interpret=True)
    want = ref.batched_slab_tq_ref(x, q)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=1e-4,
                               atol=1e-4)


def test_batched_slab_apply_matches_ref():
    key = jax.random.PRNGKey(22)
    kx, ks = jax.random.split(key)
    x = jax.random.normal(kx, (4, 8, 700))
    s = jax.random.normal(ks, (4, 700, 5))
    out = ops.batched_slab_apply(x, s, block_n=256, use_pallas=True,
                                 interpret=True)
    want = ref.batched_slab_apply_ref(x, s)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=1e-4,
                               atol=1e-3)


def test_slab_ops_zero_row_padding_exact():
    """Padded feature rows (ragged slabs stacked to d_max) stay null."""
    key = jax.random.PRNGKey(23)
    kx, kq = jax.random.split(key)
    x = jax.random.normal(kx, (2, 6, 512))
    q = jax.random.normal(kq, (2, 6, 3))
    x = x.at[1, 4:].set(0.0)        # node 1 has only 4 real features
    q = q.at[1, 4:].set(0.0)
    z = ops.batched_slab_tq(x, q, block_n=256, use_pallas=True,
                            interpret=True)
    want = ref.batched_slab_tq_ref(x[1:, :4], q[1:, :4])
    np.testing.assert_allclose(np.asarray(z[1]), np.asarray(want[0]),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# grid ops: Z[i,j] = X_ij^T Q_i and V[i,j] = X_ij S_j (fused B-DOT matmuls)
# ---------------------------------------------------------------------------
def test_grid_block_tq_matches_ref():
    """(row, column, sample-block) kernel vs fused-einsum oracle, unaligned n."""
    key = jax.random.PRNGKey(31)
    kx, kq = jax.random.split(key)
    x = jax.random.normal(kx, (3, 2, 8, 700))
    q = jax.random.normal(kq, (3, 8, 5))
    out = ops.grid_block_tq(x, q, block_n=256, use_pallas=True,
                            interpret=True)
    want = ref.grid_block_tq_ref(x, q)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=1e-4,
                               atol=1e-4)


def test_grid_block_apply_matches_ref():
    key = jax.random.PRNGKey(32)
    kx, ks = jax.random.split(key)
    x = jax.random.normal(kx, (3, 2, 8, 700))
    s = jax.random.normal(ks, (2, 700, 5))
    out = ops.grid_block_apply(x, s, block_n=256, use_pallas=True,
                               interpret=True)
    want = ref.grid_block_apply_ref(x, s)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=1e-4,
                               atol=1e-3)


def test_grid_ops_zero_padding_exact():
    """Padded feature rows AND sample columns of the (I, J) stack stay null
    (the fused B-DOT masking invariants)."""
    key = jax.random.PRNGKey(33)
    kx, kq, ks = jax.random.split(key, 3)
    x = jax.random.normal(kx, (2, 2, 6, 512))
    q = jax.random.normal(kq, (2, 6, 3))
    s = jax.random.normal(ks, (2, 512, 3))
    # block row 1 has 4 real features; grid column 1 has 400 real samples
    x = x.at[1, :, 4:].set(0.0)
    q = q.at[1, 4:].set(0.0)
    x = x.at[:, 1, :, 400:].set(0.0)
    s = s.at[1, 400:].set(0.0)
    z = ops.grid_block_tq(x, q, block_n=256, use_pallas=True, interpret=True)
    want = ref.grid_block_tq_ref(x[1:, 1:, :4, :400], q[1:, :4])
    np.testing.assert_allclose(np.asarray(z[1, 1, :400]),
                               np.asarray(want[0, 0]), rtol=1e-4, atol=1e-4)
    assert float(jnp.abs(z[1, 1, 400:]).max()) == 0.0
    v = ops.grid_block_apply(x, s, block_n=256, use_pallas=True,
                             interpret=True)
    want_v = ref.grid_block_apply_ref(x[1:, 1:, :4, :400], s[1:, :400])
    np.testing.assert_allclose(np.asarray(v[1, 1, :4]),
                               np.asarray(want_v[0, 0]), rtol=1e-4, atol=1e-3)
    assert float(jnp.abs(v[1, 1, 4:]).max()) == 0.0
