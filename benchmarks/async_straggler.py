"""Beyond-paper: asynchronous gossip under stragglers (the paper's §V
future-work). Compares synchronous S-DOT (every round blocks on the slowest
node) against async S-DOT (a busy node just sleeps through rounds) with one
persistent straggler, on error-vs-wall-clock."""
from __future__ import annotations

import numpy as np

from repro.core.async_gossip import AsyncConsensus, straggler_wall_clock
from repro.core.consensus import DenseConsensus
from repro.core.sdot import sdot
from repro.core.topology import erdos_renyi

from .common import Row, sample_problem, timed

N, R, T_O, T_C = 10, 5, 60, 50
T_ROUND, DELAY = 0.001, 0.01            # paper Table V's 10 ms straggler


def run():
    rows = []
    covs, q_true = sample_problem(d=20, r=R, n_nodes=N, n_per=500, gap=0.7,
                                  seed=0)
    g = erdos_renyi(N, 0.5, seed=1)

    # synchronous reference
    res_s, us = timed(sdot, covs=covs, engine=DenseConsensus(g), r=R,
                      t_outer=T_O, t_c=T_C, q_true=q_true)

    # async: the straggler (node 0) is awake only t_round/(t_round+delay)
    duty = T_ROUND / (T_ROUND + DELAY)
    p_awake = np.ones(N)
    p_awake[0] = duty
    eng_a = AsyncConsensus(g, p_awake=p_awake, seed=0)
    res_a, us_a = timed(sdot, covs=covs, engine=eng_a, r=R,
                        t_outer=T_O, t_c=T_C, q_true=q_true)

    wc = straggler_wall_clock(n_nodes=N, t_round=T_ROUND, delay=DELAY,
                              rounds_sync=T_O * T_C, rounds_async=T_O * T_C)
    rows.append(Row("async/sync_sdot", us, {
        "final_err": f"{res_s.error_trace[-1]:.2e}",
        "wall_clock_s": round(wc["sync_s"], 2)}))
    rows.append(Row("async/async_sdot", us_a, {
        "final_err": f"{res_a.error_trace[-1]:.2e}",
        "wall_clock_s": round(wc["async_s"], 2),
        "speedup_vs_sync": round(wc["speedup"], 1),
        "straggler_duty": round(duty, 3)}))
    return rows
