from .step import (loss_fn, make_serve_step, make_train_step,  # noqa: F401
                   make_psa_train_step)
