"""Spectrum-drift detection on the ingestor's tracked Ritz state.

The serving loop must answer one question cheaply, every tick: *is the
subspace we are serving still the subspace of the data we are ingesting?*
Re-eigendecomposing the accumulated (N, d, d) cov stack per tick would
answer it exactly and unaffordably; instead the detector reads the two
quantities ``StreamingIngestor(track_top=K)`` already maintains per
micro-batch:

* the **subspace residual** between the served iterate and the tracked
  top-K Ritz basis (paper eq. (11) — the same metric the error traces
  use). This is the primary trigger: when the stream's population rotates,
  the tracked basis follows it within a few batches and the residual
  against the frozen served subspace climbs;
* the **eigengap** estimate lambda_K - lambda_{K+1}, logged as the
  re-solve difficulty signal (Theorems 1-2: the linear rate degrades as
  the gap closes) and as a secondary trigger on relative gap collapse.

Both signals are deterministic functions of the ingested stream, so the
same stream produces the same trigger tick on every replay — which is what
makes the service's chaos trajectory reproducible.
"""
from __future__ import annotations

import dataclasses

from ..core.metrics import subspace_error

__all__ = ["DriftStats", "DriftDetector"]


@dataclasses.dataclass
class DriftStats:
    """One tick's drift reading (all host floats — metrics-friendly)."""

    residual: float       # eq. (11) between served Q and tracked top-K basis
    eigengap: float       # tracked lambda_K - lambda_{K+1} estimate
    gap_shift: float      # |eigengap - gap_at_swap| / max(gap_at_swap, eps)
    triggered: bool       # did this reading cross a threshold?


class DriftDetector:
    """Threshold detector over the ingestor's tracked spectrum.

    ``residual_threshold`` — trigger when the served subspace's residual
    against the tracked Ritz basis exceeds it (the rotation signal).
    ``gap_shift_threshold`` — trigger on relative eigengap change vs the
    gap recorded at the last swap (the spectrum-shape signal); ``None``
    disables it. ``warmup`` — ticks after a swap during which no trigger
    fires, so the Ritz iteration has time to mix and a just-swapped
    subspace is not immediately re-solved against its own transient.
    """

    def __init__(self, residual_threshold: float = 0.05,
                 gap_shift_threshold: float | None = None,
                 warmup: int = 3):
        self.residual_threshold = float(residual_threshold)
        self.gap_shift_threshold = gap_shift_threshold
        self.warmup = int(warmup)

    def read(self, ingestor, served_q, *, baseline_gap: float,
             ticks_since_swap: int) -> DriftStats:
        """One tick's reading; pure in (ingestor state, served_q)."""
        residual = float(subspace_error(ingestor.top_basis(), served_q))
        gap = ingestor.eigengap
        gap_shift = abs(gap - baseline_gap) / max(abs(baseline_gap), 1e-12)
        triggered = False
        if ticks_since_swap >= self.warmup:
            triggered = residual > self.residual_threshold
            if self.gap_shift_threshold is not None:
                triggered = triggered or gap_shift > self.gap_shift_threshold
        return DriftStats(residual=residual, eigengap=gap,
                          gap_shift=gap_shift, triggered=triggered)
