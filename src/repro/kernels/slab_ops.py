"""Pallas TPU kernels: batched feature-slab products for fused F-DOT.

F-DOT (Alg. 2) keeps node i's feature slab X_i (d_i x n) local and moves only
(n x r) partial products and (r x r) Grams over the network. Its two compute
hot spots per outer iteration are

    step 1:  Z_i = X_i^T Q_i        (d_max, n)^T (d_max, r) -> (n, r)
    step 3:  V_i = X_i S_i          (d_max, n)   (n, r)     -> (d_max, r)

batched over all N nodes (slabs zero-padded to a common d_max — exact, the
padded rows are null in both operands). Each is one kernel launch with a
(node, sample-block) grid so the fused whole-run scan stays a single
dispatch chain on TPU:

* ``batched_slab_tq_pallas``    — no accumulation: sample block j of node i
  writes its own (bn, r) output tile.
* ``batched_slab_apply_pallas`` — accumulates X_b S_b over sample blocks into
  the (d_max, r) output tile (TPU grids are sequential, so revisiting the
  output block is safe; init at j == 0 — same pattern as gram_update.py).

B-DOT (core/bdot.py) generalizes both to an I x J *grid* of blocks
X_ij (d_i x n_j): stage 1 needs Z_ij = X_ij^T Q_i and stage 2 needs
V_ij = X_ij S_j, batched over the whole grid (blocks zero-padded to a common
(d_max, n_max) — exact for the same null-operand reason). The grid kernels
below launch once with a (row, column, sample-block) grid:

* ``grid_block_tq_pallas``    — each (i, j, b) step owns its (bn, r) output
  tile of Z[i, j]; no accumulation.
* ``grid_block_apply_pallas`` — accumulates X_b S_b over sample blocks into
  the (d_max, r) tile of V[i, j] (b is the fast grid dimension; init at
  b == 0).

Call through ops.batched_slab_tq / ops.batched_slab_apply (and
ops.grid_block_tq / ops.grid_block_apply), which pad n to a block multiple
and fall back to the fused-einsum oracle off-TPU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["batched_slab_tq_pallas", "batched_slab_apply_pallas",
           "grid_block_tq_pallas", "grid_block_apply_pallas"]


def _slab_tq_kernel(x_ref, q_ref, z_ref):
    """One (i, j) grid step: Z_{i,b} = X_{i,b}^T Q_i for sample block b."""
    x = x_ref[0]            # (d, bn) — node i's sample block
    q = q_ref[0]            # (d, r)  — node i's slab iterate
    z = jax.lax.dot_general(
        x, q, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)        # X_b^T Q: (bn, r)
    z_ref[0, ...] = z.astype(z_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def batched_slab_tq_pallas(x_stack: jnp.ndarray, q_stack: jnp.ndarray, *,
                           block_n: int = 512,
                           interpret: bool = False) -> jnp.ndarray:
    """Z[i] = X_i^T Q_i for all nodes in one launch.

    x_stack: (N, d, n) with n % block_n == 0 (ops.py pads); q_stack: (N, d, r).
    Output (N, n, r) f32; each (i, j) grid step owns its output tile, so no
    accumulation is needed.
    """
    n_nodes, d, n = x_stack.shape
    n2, d2, r = q_stack.shape
    assert n_nodes == n2 and d == d2, "x_stack and q_stack must align"
    assert n % block_n == 0, "ops.py pads n to a block multiple"
    n_blocks = n // block_n

    return pl.pallas_call(
        _slab_tq_kernel,
        grid=(n_nodes, n_blocks),
        in_specs=[
            pl.BlockSpec((1, d, block_n), lambda i, j: (i, 0, j)),
            pl.BlockSpec((1, d, r), lambda i, j: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_n, r), lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((n_nodes, n, r), jnp.float32),
        interpret=interpret,
    )(x_stack, q_stack)


def _slab_apply_kernel(x_ref, s_ref, v_ref):
    """One (i, j) grid step: accumulate X_{i,b} S_{i,b} into V_i.

    j (sample block) is the fast grid dimension — node i's output tile is
    revisited consecutively; init at j == 0.
    """
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        v_ref[...] = jnp.zeros_like(v_ref)

    x = x_ref[0]            # (d, bn)
    s = s_ref[0]            # (bn, r)
    v = jax.lax.dot_general(
        x, s, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)        # X_b S_b: (d, r)
    v_ref[0, ...] += v.astype(v_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def batched_slab_apply_pallas(x_stack: jnp.ndarray, s_stack: jnp.ndarray, *,
                              block_n: int = 512,
                              interpret: bool = False) -> jnp.ndarray:
    """V[i] = X_i S_i for all nodes in one launch.

    x_stack: (N, d, n) with n % block_n == 0; s_stack: (N, n, r) (ops.py
    zero-pads the sample axis of both — exact, padded sample columns multiply
    padded S rows that are zero). Output (N, d, r) f32.
    """
    n_nodes, d, n = x_stack.shape
    n2, n3, r = s_stack.shape
    assert n_nodes == n2 and n == n3, "x_stack and s_stack must align"
    assert n % block_n == 0, "ops.py pads n to a block multiple"
    n_blocks = n // block_n

    return pl.pallas_call(
        _slab_apply_kernel,
        grid=(n_nodes, n_blocks),
        in_specs=[
            pl.BlockSpec((1, d, block_n), lambda i, j: (i, 0, j)),
            pl.BlockSpec((1, block_n, r), lambda i, j: (i, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, d, r), lambda i, j: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((n_nodes, d, r), jnp.float32),
        interpret=interpret,
    )(x_stack, s_stack)


def _grid_tq_kernel(x_ref, q_ref, z_ref):
    """One (i, j, b) grid step: Z_{ij,b} = X_{ij,b}^T Q_i for sample block b."""
    x = x_ref[0, 0]         # (d, bn) — block (i, j)'s sample block
    q = q_ref[0]            # (d, r)  — row i's slab iterate
    z = jax.lax.dot_general(
        x, q, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)        # X_b^T Q: (bn, r)
    z_ref[0, 0, ...] = z.astype(z_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def grid_block_tq_pallas(x_grid: jnp.ndarray, q_stack: jnp.ndarray, *,
                         block_n: int = 512,
                         interpret: bool = False) -> jnp.ndarray:
    """Z[i, j] = X_ij^T Q_i for every grid block in one launch (B-DOT stage 1).

    x_grid: (I, J, d, n) with n % block_n == 0 (ops.py pads); q_stack:
    (I, d, r). Output (I, J, n, r) f32; each (i, j, b) grid step owns its
    output tile, so no accumulation is needed.
    """
    i_rows, j_cols, d, n = x_grid.shape
    i2, d2, r = q_stack.shape
    assert i_rows == i2 and d == d2, "x_grid and q_stack must align"
    assert n % block_n == 0, "ops.py pads n to a block multiple"
    n_blocks = n // block_n

    return pl.pallas_call(
        _grid_tq_kernel,
        grid=(i_rows, j_cols, n_blocks),
        in_specs=[
            pl.BlockSpec((1, 1, d, block_n), lambda i, j, b: (i, j, 0, b)),
            pl.BlockSpec((1, d, r), lambda i, j, b: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_n, r),
                               lambda i, j, b: (i, j, b, 0)),
        out_shape=jax.ShapeDtypeStruct((i_rows, j_cols, n, r), jnp.float32),
        interpret=interpret,
    )(x_grid, q_stack)


def _grid_apply_kernel(x_ref, s_ref, v_ref):
    """One (i, j, b) grid step: accumulate X_{ij,b} S_{j,b} into V_ij.

    b (sample block) is the fast grid dimension — block (i, j)'s output tile
    is revisited consecutively; init at b == 0.
    """
    b = pl.program_id(2)

    @pl.when(b == 0)
    def _init():
        v_ref[...] = jnp.zeros_like(v_ref)

    x = x_ref[0, 0]         # (d, bn)
    s = s_ref[0]            # (bn, r)
    v = jax.lax.dot_general(
        x, s, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)        # X_b S_b: (d, r)
    v_ref[0, 0, ...] += v.astype(v_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def grid_block_apply_pallas(x_grid: jnp.ndarray, s_stack: jnp.ndarray, *,
                            block_n: int = 512,
                            interpret: bool = False) -> jnp.ndarray:
    """V[i, j] = X_ij S_j for every grid block in one launch (B-DOT stage 2).

    x_grid: (I, J, d, n) with n % block_n == 0; s_stack: (J, n, r) (ops.py
    zero-pads the sample axis of both — exact, padded sample columns multiply
    padded S rows that are zero). Output (I, J, d, r) f32.
    """
    i_rows, j_cols, d, n = x_grid.shape
    j2, n2, r = s_stack.shape
    assert j_cols == j2 and n == n2, "x_grid and s_stack must align"
    assert n % block_n == 0, "ops.py pads n to a block multiple"
    n_blocks = n // block_n

    return pl.pallas_call(
        _grid_apply_kernel,
        grid=(i_rows, j_cols, n_blocks),
        in_specs=[
            pl.BlockSpec((1, 1, d, block_n), lambda i, j, b: (i, j, 0, b)),
            pl.BlockSpec((1, block_n, r), lambda i, j, b: (j, b, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, d, r), lambda i, j, b: (i, j, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((i_rows, j_cols, d, r), jnp.float32),
        interpret=interpret,
    )(x_grid, s_stack)
