"""Tables VI-IX — real-world datasets (MNIST / CIFAR-10 / LFW / ImageNet).

This container is offline, so the raw datasets are replaced by
*spectrum-matched synthetic stand-ins*: same d, per-node n_i, N, r; a
power-law covariance spectrum fitted to natural-image decay (see
data/pipeline.spectrum_matched_data). What is validated:

  * P2P counts — exact (they depend only on topology x schedule, not data);
  * the comm/convergence trade-off shape (SA-DOT cheaper, same floor).

The LFW and ImageNet rows use the paper's reduced per-node sample counts.
d is kept at the dataset's true dimension; n_i is scaled down ~4x where the
full covariance stack would be slow on this CPU container (noted per row —
P2P columns are unaffected).
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.consensus import DenseConsensus, consensus_schedule
from repro.core.linalg import eigh_topr
from repro.core.sdot import sdot
from repro.core.topology import erdos_renyi
from repro.data.pipeline import partition_samples, spectrum_matched_data

from .common import Row, timed

# dataset stand-ins: (d, n_total, default r)
DATASETS = {
    "mnist": (784, 12_000, 5),
    "cifar10": (1024, 12_000, 5),
    "lfw": (2914, 6_000, 7),
    "imagenet": (1024, 12_000, 5),
}

CASES = [
    # (dataset, N, p, r, T_o, schedules)
    ("mnist", 20, 0.25, 5, 100, ("t+1", "2t+1", "50")),
    ("mnist", 100, 0.05, 5, 50, ("t+1", "2t+1", "50")),
    ("cifar10", 20, 0.25, 7, 100, ("t+1", "2t+1", "50")),
    ("lfw", 20, 0.25, 7, 60, ("t+1", "50")),
    ("imagenet", 20, 0.25, 5, 100, ("t+1", "2t+1", "50")),
    ("imagenet", 100, 0.05, 5, 50, ("t+1", "50")),
]

_SCHED = {"t+1": ("lin1", 50), "2t+1": ("lin2", 50), "50": ("const", None)}


def run():
    rows = []
    cache = {}
    for ds, n_nodes, p, r, t_o, schedules in CASES:
        d, n_total, _ = DATASETS[ds]
        key = (ds, n_nodes)
        if key not in cache:
            x = spectrum_matched_data(d, n_total, seed=0)
            blocks = partition_samples(x, n_nodes)
            covs = jnp.stack([b @ b.T / b.shape[1] for b in blocks])
            _, q_true = eigh_topr(covs.sum(0), max(r, 7))
            cache[key] = (covs, q_true)
        covs, q_true_full = cache[key]
        q_true = q_true_full[:, :r]
        g = erdos_renyi(n_nodes, p, seed=1)
        eng = DenseConsensus(g)
        for label in schedules:
            kind, cap = _SCHED[label]
            sched = consensus_schedule(kind, t_o, t_max=50, cap=cap)
            res, us = timed(sdot, covs=covs, engine=eng, r=r, t_outer=t_o,
                            schedule=sched, q_true=q_true)
            rows.append(Row(
                f"table69/{ds}/N{n_nodes}/r{r}/Tc={label}", us,
                {"p2p_k": round(res.ledger.per_node_p2p(n_nodes) / 1e3, 2),
                 "final_err": f"{res.error_trace[-1]:.2e}",
                 "d": d, "T_o": t_o}))
    return rows
