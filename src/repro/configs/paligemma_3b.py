"""paligemma-3b — SigLIP + gemma backbone [arXiv:2407.07726; hf].

The SigLIP vision tower is a STUB: input_specs() supplies precomputed patch
embeddings (256 prefix positions) spliced over the text embedding prefix.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="paligemma-3b", family="vlm",
    n_layers=18, d_model=2048, n_heads=8, n_kv_heads=1,
    d_ff=16_384, vocab_size=257_216,
    block_pattern=("attn",),
    frontend="vlm_patches", n_prefix_tokens=256,
)
