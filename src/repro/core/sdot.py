"""S-DOT and SA-DOT — sample-wise distributed orthogonal iteration (Alg. 1).

The two algorithms share one implementation; they differ only in the
per-outer-iteration consensus budget ``schedule`` (constant for S-DOT,
increasing for SA-DOT — see ``consensus_schedule``).

Engines:
  * ``sdot`` — simulation over an explicit graph (DenseConsensus). All N node
    states are carried as a stacked (N, d, r) array; this is what reproduces
    the paper's tables.
  * ``sdot_spmd_step`` — the building block used when node == TPU pod; exact
    psum intra-pod, gossip inter-pod (see optim/psa_compress.py).

Execution modes (``fused`` flag):
  * fused (default) — the ENTIRE run is one jitted ``lax.scan`` over outer
    iterations: per-iteration consensus budgets are read from the schedule
    array, the inner gossip is a masked scan (so varying T_{c,t} stays
    traceable), debiasing indexes a precomputed device table of W^t e_1
    rows, and the error trace is computed on device and returned as one
    (T_o,) array. Zero host syncs per iteration, one compile per
    (shapes, t_max) signature, communication accounted in closed form.
  * eager (``fused=False``) — the original Python loop, one dispatch chain
    per outer iteration. Kept as the bit-level correctness oracle
    (tests/test_sdot_fused.py) and for step-by-step debugging.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from .consensus import DenseConsensus, consensus_schedule, debiased_gossip
from .linalg import cholesky_qr2, orthonormal_init
from .metrics import CommLedger, mean_subspace_error, subspace_error
from ..kernels import ops as kops

__all__ = ["SDOTResult", "sdot", "sadot", "local_cov_apply"]


@dataclasses.dataclass
class SDOTResult:
    q_nodes: jnp.ndarray            # (N, d, r) final per-node estimates
    error_trace: Optional[np.ndarray]   # (T_o,) mean subspace error vs q_true
    consensus_trace: np.ndarray     # (T_o,) consensus rounds used per outer iter
    ledger: CommLedger              # communication accounting

    @property
    def q_mean(self) -> jnp.ndarray:
        """Consensus-averaged estimate (for reporting; nodes already agree)."""
        return self.q_nodes.mean(axis=0)


def local_cov_apply(covs: jnp.ndarray, q_nodes: jnp.ndarray) -> jnp.ndarray:
    """Step 5 of Alg. 1 at every node: Z_i = M_i Q_i. covs: (N,d,d)."""
    return jnp.einsum("nde,ner->ndr", covs, q_nodes)


def _stack_data(xs: Sequence[jnp.ndarray]):
    """Zero-pad ragged node blocks (d, n_i) to one (N, d, n_max) stack.

    Padding is exact for the gram apply (padded columns are null in both
    matmuls); the true n_i go along for the normalizer.
    """
    n_true = np.array([x.shape[1] for x in xs], np.float32)
    n_max = int(n_true.max())
    stack = jnp.stack([
        jnp.pad(x, ((0, 0), (0, n_max - x.shape[1]))) for x in xs])
    return stack, jnp.asarray(n_true)


def _make_data_apply(xs: Sequence[jnp.ndarray]) -> Callable:
    """Gram-free Step 5: Z_i = X_i (X_i^T Q_i), never forming M_i (d x d).

    All nodes are served by ONE batched gram-apply dispatch (Pallas
    (node, column-block) kernel on TPU, fused einsum elsewhere) instead of a
    per-node Python loop — mandatory for the fused executor, and fewer
    dispatches for the eager one too.
    """
    stack, n_true = _stack_data(xs)

    def apply(q_nodes):
        return kops.batched_gram_apply(stack, q_nodes, n_true)

    return apply


@functools.partial(jax.jit, static_argnames=("mode", "t_max", "trace_err"))
def _fused_run(operand, w, table, sched, q0_nodes, q_true, *, mode: str,
               t_max: int, trace_err: bool):
    """One compiled program for a whole S-DOT/SA-DOT run.

    operand: covs (N,d,d) for mode='cov'; (x_stack, n_true) for mode='data'.
    sched: (T_o,) int32 consensus budgets; t_max: static max budget (inner
    masked-scan length); table: (t_max+1, N) debias rows [W^t e_1].
    Returns (q_nodes, (T_o,) error trace — zeros when trace_err is False).
    """

    def apply_fn(q_nodes):
        if mode == "cov":
            return local_cov_apply(operand, q_nodes)
        x_stack, n_true = operand
        return kops.batched_gram_apply(x_stack, q_nodes, n_true)

    def outer(q_nodes, t_c):
        z0 = apply_fn(q_nodes)                                   # (N, d, r)
        v = debiased_gossip(w, table, z0, t_c, t_max)
        q_new = jax.vmap(lambda vv: cholesky_qr2(vv)[0])(v)      # per-node QR
        err = (mean_subspace_error(q_true, q_new) if trace_err
               else jnp.float32(0.0))
        return q_new, err

    return jax.lax.scan(outer, q0_nodes, sched)


def sdot(
    *,
    covs: Optional[jnp.ndarray] = None,
    data: Optional[Sequence[jnp.ndarray]] = None,
    engine: DenseConsensus,
    r: int,
    t_outer: int,
    schedule: Optional[np.ndarray] = None,
    t_c: int = 50,
    q_init: Optional[jnp.ndarray] = None,
    q_true: Optional[jnp.ndarray] = None,
    seed: int = 0,
    fused: bool = True,
) -> SDOTResult:
    """Run S-DOT / SA-DOT over a simulated network.

    Exactly one of ``covs`` (N, d, d) or ``data`` (list of (d, n_i)) must be
    given. ``schedule`` overrides ``t_c`` (constant) and makes this SA-DOT.
    ``fused=True`` (default) executes the whole run as a single compiled
    scan; ``fused=False`` is the eager per-iteration oracle.
    """
    if (covs is None) == (data is None):
        raise ValueError("provide exactly one of covs / data")
    n = engine.graph.n_nodes
    if covs is not None:
        d = covs.shape[1]
        if covs.shape[0] != n:
            raise ValueError("covs leading dim must equal number of nodes")
    else:
        d = data[0].shape[0]
        if len(data) != n:
            raise ValueError("need one data block per node")

    if schedule is None:
        schedule = consensus_schedule("const", t_outer, t_max=t_c)
    elif len(schedule) < t_outer:
        # fail loudly: the fused scan would silently truncate the run and
        # the eager loop would IndexError mid-flight
        raise ValueError(f"schedule has {len(schedule)} entries but "
                         f"t_outer={t_outer}")
    if q_init is None:
        q_init = orthonormal_init(jax.random.PRNGKey(seed), d, r)
    # all nodes start from the same Q_init (Theorem 1 requires it)
    q_nodes = jnp.broadcast_to(q_init[None], (n, d, r))

    ledger = CommLedger()
    payload = d * r

    # engines without the whole-run scan interface (e.g. AsyncConsensus,
    # whose realized round matrices are sampled per run_debiased call) run
    # the eager loop — each consensus call is still one device dispatch
    if fused and not hasattr(engine, "debias_table"):
        fused = False

    if fused:
        t_max = int(np.asarray(schedule[:t_outer]).max()) if t_outer else 0
        table = engine.debias_table(t_max)
        sched_dev = jnp.asarray(np.asarray(schedule[:t_outer]), jnp.int32)
        if covs is not None:
            operand, mode = covs, "cov"
        else:
            operand, mode = _stack_data(data), "data"
        trace_err = q_true is not None
        q_arg = q_true if trace_err else jnp.zeros((d, r), q_nodes.dtype)
        q_nodes, errs = _fused_run(
            operand, engine._w, table, sched_dev, q_nodes, q_arg,
            mode=mode, t_max=t_max, trace_err=trace_err)
        ledger.log_gossip_rounds(schedule[:t_outer], engine.graph.adjacency,
                                 payload)
        error_trace = np.asarray(errs) if trace_err else None
    else:
        apply_fn = ((lambda q: local_cov_apply(covs, q)) if covs is not None
                    else _make_data_apply(data))
        errs = [] if q_true is not None else None
        for t in range(t_outer):
            z0 = apply_fn(q_nodes)                                # (N, d, r)
            v = engine.run_debiased(z0, int(schedule[t]), ledger)
            q_nodes = jax.vmap(lambda vv: cholesky_qr2(vv)[0])(v)
            if errs is not None:
                e = jax.vmap(lambda qq: subspace_error(q_true, qq))(q_nodes)
                errs.append(float(e.mean()))
        error_trace = np.asarray(errs) if errs is not None else None

    return SDOTResult(
        q_nodes=q_nodes,
        error_trace=error_trace,
        consensus_trace=np.asarray(schedule[:t_outer]),
        ledger=ledger,
    )


def sadot(*, schedule_kind: str = "lin2", cap: Optional[int] = None,
          t_outer: int, **kw) -> SDOTResult:
    """SA-DOT convenience wrapper: increasing consensus schedule."""
    sched = consensus_schedule(schedule_kind, t_outer, cap=cap)
    return sdot(t_outer=t_outer, schedule=sched, **kw)
