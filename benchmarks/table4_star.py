"""Table IV — star topology: the hub relays everything, so its P2P count is
(N-1)x every edge node's — the central-bottleneck effect."""
from __future__ import annotations

import numpy as np

from repro.core.consensus import DenseConsensus, consensus_schedule
from repro.core.sdot import sdot
from repro.core.topology import star

from .common import Row, sample_problem, timed

N, R, T_O = 20, 5, 200


def run():
    rows = []
    covs, q_true = sample_problem(d=20, r=R, n_nodes=N, n_per=500, gap=0.7,
                                  seed=0)
    g = star(N)
    eng = DenseConsensus(g)
    for label, kind, t_max, cap in (
            ("2t+1", "lin2", 50, 50), ("50", "const", 50, None),
            ("min(2t+1,100)", "lin2", 100, 100),
            ("min(5t+1,100)", "lin5", 100, 100),
            ("100", "const", 100, None)):
        sched = consensus_schedule(kind, T_O, t_max=t_max, cap=cap)
        res, us = timed(sdot, covs=covs, engine=eng, r=R, t_outer=T_O,
                        schedule=sched, q_true=q_true)
        rounds = int(sched.sum())
        center_k = g.degrees[0] * rounds / 1e3
        edge_k = g.degrees[1] * rounds / 1e3
        rows.append(Row(
            f"table4/star/Tc={label}", us,
            {"center_p2p_k": round(center_k, 2),
             "edge_p2p_k": round(edge_k, 2),
             "final_err": f"{res.error_trace[-1]:.2e}"}))
    return rows
