# Streaming PSA subsystem: online covariance ingestion (ingest.py),
# chunked-resumable fused runs (resume.py), and the multi-host sweep
# launcher (launcher.py / worker.py). Nothing here may import at package
# level that launch/dryrun.py cannot tolerate — keep this module empty of
# jax imports so `python -m repro.streaming.worker` controls its own flags.
