"""Seeded fault-injection harness for the sweep fleet.

The paper's Table-V straggler study shows one slow machine dominating a
serverless sweep; this module applies the same adversary to our own fleet
so the launcher's recovery paths (heartbeat supervision, retry budgets,
lease stealing, checkpoint fallback) are *proven* rather than assumed. A
``FaultPlan`` is a small, seeded, declarative JSON document:

    {"seed": 0, "faults": [
        {"kind": "kill",    "shard": 0},                  # SIGKILL at a
                                                          # seeded chunk
                                                          # boundary
        {"kind": "corrupt", "shard": 1, "mode": "truncate"},
                                                          # tear the newest
                                                          # ckpt, then die
        {"kind": "slow",    "worker": 0, "factor": 10.0}, # straggler model
        {"kind": "slow",    "shard": 2, "sleep": 0.5},    # fixed per-chunk
        {"kind": "hang",    "shard": 3, "sleep": 600},    # wedge (no exit,
                                                          # no heartbeat)
        {"kind": "drop",    "shard": 4},                  # lose the
                                                          # published result
    ]}

Injection is wired through ENV VARS so production code carries no chaos
branches: the worker unconditionally calls ``hooks_from_env(...)`` and the
returned hooks are no-ops unless ``REPRO_CHAOS_PLAN`` names a plan file.
One-shot faults (kill / corrupt / hang / drop) record a marker file under
``<workdir>/chaos_state/`` *before* firing, so a relaunched worker does not
re-fire them — every chaos run terminates, and the recovered result can be
asserted bit-identical to the fault-free sweep (tests/test_chaos.py, the
CI chaos-smoke job, and ``python -m repro.streaming.chaos --smoke``).

Fault semantics:

* ``kill``: at a chunk boundary chosen by the plan's seeded RNG (or a
  pinned ``"boundary"``), SIGKILL the worker process. Recovery: the
  launcher's poll loop sees the death in ~one poll interval and relaunches
  with backoff; the relaunch resumes from the shard's sweep-RunState
  checkpoint.
* ``corrupt``: at a seeded boundary, tear the newest checkpoint step —
  ``truncate`` halves ``shards.npz``, ``garbage`` overwrites it,
  ``manifest`` deletes ``manifest.json`` (a torn dir ``latest_step`` must
  skip) — then SIGKILL. Recovery: restore falls back to the newest
  restorable step (``runtime._restore_any``).
* ``slow``: the paper's straggler model applied per worker: every chunk
  boundary sleeps ``(factor - 1) x`` the measured chunk walltime (or a
  fixed ``sleep``). Never one-shot. Recovery: lease expiry + work
  stealing (elastic mode) or simply a slower shard (pinned mode).
* ``hang``: sleep ``sleep`` seconds at a seeded boundary without exiting —
  a wedged worker that stays alive but stops heartbeating. Recovery:
  stale-heartbeat supervision kills and relaunches it.
* ``drop``: delete the freshly published result directory (a lost
  publish). The worker still exits 0 — recovery is the launcher treating
  rc==0 with no valid result as a failure and retrying.
* ``delay_query``: seeded per-request added latency on the SERVING query
  path (``{"kind": "delay_query", "p": 0.5, "delay": 0.05}``): request
  ``req_id`` is delayed iff its (plan seed, fault index, req_id)-keyed draw
  lands under ``p`` — deterministic, so a serving bench can exercise
  deadline expiry and load shedding reproducibly. Never one-shot; fires
  from ``ChaosHooks.query_delay``, not at chunk boundaries. Recovery: the
  query path's deadline check sheds the late request explicitly.
* ``corrupt_candidate``: one-shot mangling of a re-solve's CANDIDATE
  subspace right before the serving quality gate (``mode`` nan | scale) —
  the adversary for the gate itself. Fires from
  ``ChaosHooks.mangle_candidate``; an optional ``"resolve"`` field pins it
  to one re-solve id. Recovery: the gate must reject the candidate, keep
  serving the incumbent, and fall back to a cold re-solve.
"""
from __future__ import annotations

import json
import os
import signal
import time
from typing import List, Optional

import numpy as np

from ..obs import get_journal

__all__ = ["FaultPlan", "ChaosHooks", "hooks_from_env", "ENV_PLAN",
           "ENV_NET", "validate_net_fault_doc", "net_fault_model_from_dict",
           "net_faults_from_env"]

ENV_PLAN = "REPRO_CHAOS_PLAN"
ENV_NET = "REPRO_NET_FAULTS"
_STATE_DIR = "chaos_state"

_KINDS = ("kill", "corrupt", "slow", "hang", "drop", "delay_query",
          "corrupt_candidate")
_ONE_SHOT = ("kill", "corrupt", "hang", "drop", "corrupt_candidate")


class FaultPlan:
    """Declarative, seeded fault schedule (see module docstring)."""

    def __init__(self, faults: List[dict], seed: int = 0):
        for i, f in enumerate(faults):
            kind = f.get("kind")
            if kind not in _KINDS:
                raise ValueError(f"fault {i}: unknown kind {kind!r}"
                                 f" (expected one of {_KINDS})")
            if kind == "delay_query":
                p = f.get("p", 1.0)
                if not isinstance(p, (int, float)) or isinstance(p, bool) \
                        or not 0.0 <= float(p) <= 1.0:
                    raise ValueError(f"fault {i}: delay_query.p must be a "
                                     f"number in [0, 1], got {p!r}")
                delay = f.get("delay", 0.05)
                if not isinstance(delay, (int, float)) \
                        or isinstance(delay, bool) or float(delay) < 0.0:
                    raise ValueError(f"fault {i}: delay_query.delay must be "
                                     f"a number >= 0 (seconds), got {delay!r}")
            if kind == "corrupt_candidate" \
                    and f.get("mode", "nan") not in ("nan", "scale"):
                raise ValueError(f"fault {i}: corrupt_candidate.mode must be "
                                 f"'nan' or 'scale', got {f.get('mode')!r}")
        self.faults = list(faults)
        self.seed = int(seed)

    @classmethod
    def load(cls, path: str) -> "FaultPlan":
        with open(path) as f:
            doc = json.load(f)
        return cls(doc.get("faults", []), seed=doc.get("seed", 0))

    def dump(self, path: str) -> str:
        with open(path, "w") as f:
            json.dump({"seed": self.seed, "faults": self.faults}, f,
                      indent=2)
        return path

    def boundary_for(self, fault_idx: int, n_boundaries: int) -> int:
        """The 1-indexed chunk boundary at which fault ``fault_idx`` fires.

        Deterministic in (plan seed, fault index): the same plan replayed
        against the same grid kills/corrupts at the same boundary, so chaos
        runs are reproducible end to end."""
        fault = self.faults[fault_idx]
        if fault.get("boundary") is not None:
            return int(fault["boundary"])
        rng = np.random.default_rng(self.seed * 7919 + fault_idx)
        return int(rng.integers(1, max(2, n_boundaries + 1)))


def _matches(fault: dict, shard: Optional[int], worker: Optional[str]) -> bool:
    """A fault applies when every target it names matches this process.

    ``shard`` targets the work item (kill/corrupt/drop travel with the
    shard's state); ``worker`` targets the process identity — ``"w<k>"`` for
    fleet workers, the shard index for pinned workers — which is the right
    axis for the straggler model (a slow *machine*, whatever it runs)."""
    if "shard" in fault and (shard is None or int(fault["shard"]) != shard):
        return False
    if "worker" in fault:
        want = str(fault["worker"])
        have = "" if worker is None else str(worker)
        if want != have and f"w{want}" != have:
            return False
    return True


class ChaosHooks:
    """Per-process injection hooks; a no-op shell when ``plan`` is None.

    ``at_boundary(step)`` is invoked from the checkpoint manager's
    ``on_save`` callback (every chunk boundary); ``after_publish(out_dir)``
    right after the worker publishes its result; ``query_delay(req_id)``
    from a serving query path per admitted request; ``mangle_candidate``
    from the serving quality gate on each re-solve candidate.

    ``step_boundaries=True`` anchors boundary matching to the SAVED STEP
    NUMBER instead of this process's save count: a long-lived service whose
    step counter survives restarts (the serving tick) wants fault
    boundaries pinned to absolute ticks, so a plan reads the same before
    and after a crash — a worker's per-attempt count restarts from zero,
    which is the right axis for the sweep fleet but not for a service.
    """

    def __init__(self, plan: Optional[FaultPlan], *, shard=None, worker=None,
                 n_boundaries: int = 1, ckpt_root: Optional[str] = None,
                 state_dir: Optional[str] = None,
                 step_boundaries: bool = False):
        self.plan = plan
        self.shard = None if shard is None else int(shard)
        self.worker = None if worker is None else str(worker)
        self.n_boundaries = max(1, int(n_boundaries))
        self.ckpt_root = ckpt_root
        self.state_dir = state_dir
        self.step_boundaries = bool(step_boundaries)
        self._boundary = 0
        self._last_t = time.monotonic()
        if plan is not None and state_dir:
            os.makedirs(state_dir, exist_ok=True)

    @property
    def active(self) -> bool:
        return self.plan is not None

    # -- one-shot bookkeeping -------------------------------------------
    def _marker(self, idx: int) -> str:
        tag = f"fired_{idx}" + ("" if self.shard is None
                                else f"_s{self.shard}")
        return os.path.join(self.state_dir or ".", tag)

    def _fired(self, idx: int) -> bool:
        return os.path.exists(self._marker(idx))

    def _mark(self, idx: int) -> None:
        # the marker lands BEFORE the fault executes: a SIGKILL mid-fault
        # must not re-arm it on relaunch
        with open(self._marker(idx), "w") as f:
            f.write(str(time.time()))
            f.flush()
            os.fsync(f.fileno())

    def _journal(self, idx: int, kind: str, **fields) -> None:
        # also BEFORE the fault executes: the journal append is one atomic
        # os.write, so even a self-SIGKILL on the next line leaves the
        # firing attributable from the trace (the forensics CLI matches
        # these records against the plan by fault index)
        # "kind" is reserved record schema (event/span_start/span), so the
        # fault's kind travels as fault_kind
        get_journal().event("chaos_fired", "chaos", fault=idx,
                            fault_kind=kind, boundary=self._boundary,
                            shard=self.shard, worker=self.worker, **fields)

    # -- fault executors -------------------------------------------------
    def _corrupt_newest(self, mode: str) -> None:
        root = self.ckpt_root
        if not root or not os.path.isdir(root):
            return
        steps = sorted(n for n in os.listdir(root)
                       if n.startswith("step_") and ".tmp" not in n)
        if not steps:
            return
        newest = os.path.join(root, steps[-1])
        shard_file = os.path.join(newest, "shards.npz")
        if mode == "manifest":
            os.remove(os.path.join(newest, "manifest.json"))
        elif mode == "truncate" and os.path.exists(shard_file):
            size = os.path.getsize(shard_file)
            with open(shard_file, "r+b") as f:
                f.truncate(size // 2)
        else:  # "garbage"
            with open(shard_file, "wb") as f:
                f.write(b"chaos: not an npz")

    # -- hook entry points -----------------------------------------------
    def at_boundary(self, step: int) -> None:
        if self.plan is None:
            return
        if self.step_boundaries:
            self._boundary = int(step)
        else:
            self._boundary += 1
        elapsed = time.monotonic() - self._last_t
        self._last_t = time.monotonic()
        for idx, fault in enumerate(self.plan.faults):
            if not _matches(fault, self.shard, self.worker):
                continue
            kind = fault["kind"]
            if kind in ("delay_query", "corrupt_candidate"):
                continue  # fire from the serving hooks, not at boundaries
            if kind == "slow":
                if "sleep" in fault:
                    pause = float(fault["sleep"])
                else:
                    pause = max(0.0, (float(fault.get("factor", 2.0))
                                      - 1.0) * elapsed)
                self._journal(idx, kind, sleep_s=round(pause, 6))
                time.sleep(pause)
                continue
            if kind == "drop":
                continue  # fires at publish time
            if self._boundary != self.plan.boundary_for(
                    idx, self.n_boundaries) or self._fired(idx):
                continue
            self._mark(idx)
            self._journal(idx, kind, step=step)
            if kind == "hang":
                time.sleep(float(fault.get("sleep", 600.0)))
            elif kind == "corrupt":
                self._corrupt_newest(fault.get("mode", "garbage"))
                os.kill(os.getpid(), signal.SIGKILL)
            elif kind == "kill":
                os.kill(os.getpid(), signal.SIGKILL)

    def after_publish(self, out_dir: str) -> None:
        if self.plan is None:
            return
        for idx, fault in enumerate(self.plan.faults):
            if (fault["kind"] == "drop"
                    and _matches(fault, self.shard, self.worker)
                    and not self._fired(idx)):
                self._mark(idx)
                self._journal(idx, "drop", out_dir=out_dir)
                import shutil
                shutil.rmtree(out_dir, ignore_errors=True)

    def query_delay(self, req_id: int) -> float:
        """Seconds of injected latency for request ``req_id`` (0.0 inert).

        Deterministic in (plan seed, fault index, req_id): the same plan
        delays the same requests on every run, so deadline-expiry and
        load-shedding behaviour is reproducible. The caller adds the delay
        to its service time (sleep or simulated clock)."""
        if self.plan is None:
            return 0.0
        total = 0.0
        for idx, fault in enumerate(self.plan.faults):
            if fault["kind"] != "delay_query" \
                    or not _matches(fault, self.shard, self.worker):
                continue
            rng = np.random.default_rng(
                self.plan.seed * 7919 + (idx + 1) * 104729 + int(req_id))
            if rng.random() < float(fault.get("p", 1.0)):
                delay = float(fault.get("delay", 0.05))
                self._journal(idx, "delay_query", req_id=int(req_id),
                              delay_s=delay)
                total += delay
        return total

    def mangle_candidate(self, q, resolve_id: int):
        """One-shot corruption of a re-solve candidate before the gate.

        ``mode`` "nan" poisons one entry; "scale" blows the candidate up by
        ``scale`` (default 1e9, destroying orthonormality). An optional
        ``"resolve"`` field pins the fault to one re-solve id; without it
        the first candidate to pass through is hit. Returns the (possibly
        corrupted) candidate."""
        if self.plan is None:
            return q
        for idx, fault in enumerate(self.plan.faults):
            if fault["kind"] != "corrupt_candidate" \
                    or not _matches(fault, self.shard, self.worker) \
                    or self._fired(idx):
                continue
            if fault.get("resolve") is not None \
                    and int(fault["resolve"]) != int(resolve_id):
                continue
            self._mark(idx)
            self._journal(idx, "corrupt_candidate",
                          resolve=int(resolve_id),
                          mode=fault.get("mode", "nan"))
            arr = np.array(q, np.float32, copy=True)
            if fault.get("mode", "nan") == "nan":
                arr.flat[0] = np.nan
            else:
                arr *= float(fault.get("scale", 1e9))
            q = arr
        return q


def hooks_from_env(*, shard=None, worker=None, n_boundaries: int = 1,
                   ckpt_root: Optional[str] = None,
                   workdir: Optional[str] = None,
                   step_boundaries: bool = False) -> ChaosHooks:
    """The worker's single chaos entry point.

    Without ``REPRO_CHAOS_PLAN`` in the environment this returns inert
    hooks — the production path never branches on chaos, it just calls
    methods that do nothing."""
    path = os.environ.get(ENV_PLAN)
    if not path:
        return ChaosHooks(None)
    plan = FaultPlan.load(path)
    state_dir = os.path.join(workdir or os.path.dirname(path), _STATE_DIR)
    return ChaosHooks(plan, shard=shard, worker=worker,
                      n_boundaries=n_boundaries, ckpt_root=ckpt_root,
                      state_dir=state_dir, step_boundaries=step_boundaries)


# ---------------------------------------------------------------------------
# network-fault plans (the gossip-layer twin of FaultPlan)
# ---------------------------------------------------------------------------
# FaultPlan injects PROCESS faults (kill/corrupt/slow/hang/drop);
# REPRO_NET_FAULTS injects NETWORK faults into the gossip itself — link
# drops, Gilbert–Elliott bursts, node crash/rejoin, payload corruption —
# via core.netfaults.FaultyConsensus. Same conventions: a small seeded
# declarative JSON document, wired through an env var so production code
# carries no fault branches:
#
#     {"seed": 0, "p_drop": 0.2,
#      "burst": {"p_bad": 0.05, "p_good": 0.5},
#      "corrupt": {"p": 0.01, "mode": "scale", "scale": 1e9, "guard": 1e6},
#      "crash": [{"node": 0, "start": 2, "len": 3}],
#      "debias": "realized"}
#
# Every field is optional (an empty document is the fault-free model).

def _num_field(doc, key, lo=None, hi=None, path=""):
    v = doc[key]
    label = f"{path}{key}"
    if not isinstance(v, (int, float)) or isinstance(v, bool):
        raise ValueError(f"{label}: expected a number, got {v!r}")
    v = float(v)
    if lo is not None and v < lo or hi is not None and v > hi:
        rng = (f"[{lo}, {hi}]" if hi is not None else f">= {lo}")
        raise ValueError(f"{label}: must be in {rng}, got {v}")
    return v


def validate_net_fault_doc(doc: dict) -> dict:
    """Validate a net-fault JSON document, raising ``ValueError`` with a
    field-path diagnostic (``crash[1].len: must be a positive integer``)
    on the first malformed field. Returns the parsed document unchanged."""
    if not isinstance(doc, dict):
        raise ValueError(f"net-fault plan: expected a JSON object, got "
                         f"{type(doc).__name__}")
    known = {"seed", "p_drop", "burst", "corrupt", "crash", "debias"}
    for k in doc:
        if k not in known:
            raise ValueError(f"{k}: unknown field (expected one of "
                             f"{sorted(known)})")
    if "seed" in doc and not isinstance(doc["seed"], int):
        raise ValueError(f"seed: expected an integer, got {doc['seed']!r}")
    if "p_drop" in doc:
        _num_field(doc, "p_drop", 0.0, 1.0)
    if "burst" in doc:
        burst = doc["burst"]
        if not isinstance(burst, dict):
            raise ValueError(f"burst: expected an object, got {burst!r}")
        for k in burst:
            if k not in ("p_bad", "p_good"):
                raise ValueError(f"burst.{k}: unknown field")
            _num_field(burst, k, 0.0, 1.0, path="burst.")
        if burst.get("p_bad", 0.0) > 0.0 and burst.get("p_good", 1.0) <= 0.0:
            raise ValueError("burst.p_good: must be > 0 when burst.p_bad "
                             "> 0 (a burst must be able to end)")
    if "corrupt" in doc:
        cor = doc["corrupt"]
        if not isinstance(cor, dict):
            raise ValueError(f"corrupt: expected an object, got {cor!r}")
        for k in cor:
            if k not in ("p", "mode", "scale", "guard"):
                raise ValueError(f"corrupt.{k}: unknown field")
        if "p" in cor:
            _num_field(cor, "p", 0.0, 1.0, path="corrupt.")
        if cor.get("mode", "scale") not in ("scale", "nan"):
            raise ValueError(f"corrupt.mode: expected 'scale' or 'nan', "
                             f"got {cor.get('mode')!r}")
        for k in ("scale", "guard"):
            if k in cor and _num_field(cor, k, path="corrupt.") <= 0.0:
                raise ValueError(f"corrupt.{k}: must be > 0")
    if "crash" in doc:
        crash = doc["crash"]
        if not isinstance(crash, list):
            raise ValueError(f"crash: expected a list, got {crash!r}")
        for i, win in enumerate(crash):
            if not isinstance(win, dict):
                raise ValueError(f"crash[{i}]: expected an object")
            for k in ("node", "start", "len"):
                if k not in win:
                    raise ValueError(f"crash[{i}].{k}: missing")
                if not isinstance(win[k], int) or isinstance(win[k], bool):
                    raise ValueError(f"crash[{i}].{k}: expected an integer,"
                                     f" got {win[k]!r}")
            if win["node"] < 0:
                raise ValueError(f"crash[{i}].node: must be >= 0")
            if win["start"] < 0:
                raise ValueError(f"crash[{i}].start: must be >= 0")
            if win["len"] <= 0:
                raise ValueError(f"crash[{i}].len: must be a positive "
                                 "integer")
    if doc.get("debias", "realized") not in ("realized", "nominal"):
        raise ValueError(f"debias: expected 'realized' or 'nominal', got "
                         f"{doc.get('debias')!r}")
    return doc


def net_fault_model_from_dict(doc: dict):
    """Build the ``core.netfaults.NetFaultModel`` a validated document
    describes. Returns ``(model, seed, debias)`` — the pieces a worker
    needs to wrap each case engine in a ``FaultyConsensus``. Imported
    lazily so plan validation stays jax-free."""
    from ..core.netfaults import NetFaultModel

    validate_net_fault_doc(doc)
    burst = doc.get("burst", {})
    cor = doc.get("corrupt", {})
    model = NetFaultModel(
        p_drop=float(doc.get("p_drop", 0.0)),
        p_bad=float(burst.get("p_bad", 0.0)),
        p_good=float(burst.get("p_good", 1.0)),
        p_corrupt=float(cor.get("p", 0.0)),
        corrupt_mode=cor.get("mode", "scale"),
        corrupt_scale=float(cor.get("scale", 1e9)),
        guard_norm=float(cor.get("guard", 1e6)),
        crash_windows=tuple((int(w["node"]), int(w["start"]), int(w["len"]))
                            for w in doc.get("crash", ())),
    )
    return model, int(doc.get("seed", 0)), doc.get("debias", "realized")


def net_faults_from_env() -> Optional[dict]:
    """The launcher's net-fault entry point: ``REPRO_NET_FAULTS`` names a
    plan file (or holds inline JSON, for one-liners); absent -> None and
    the production path never branches on faults."""
    spec = os.environ.get(ENV_NET)
    if not spec:
        return None
    if spec.lstrip().startswith("{"):
        doc = json.loads(spec)
    else:
        with open(spec) as f:
            doc = json.load(f)
    return validate_net_fault_doc(doc)


def validate_plan_file(path: str, verbose: bool = True) -> int:
    """``--validate`` mode: check a chaos/net-fault plan file, printing a
    line/field diagnostic for malformed plans. Auto-detects the plan kind
    (a ``"faults"`` key -> process FaultPlan, else net-fault document).
    Returns a process exit code (0 valid, 1 invalid)."""
    try:
        with open(path) as f:
            doc = json.load(f)
    except OSError as e:
        print(f"{path}: unreadable: {e}")
        return 1
    except json.JSONDecodeError as e:
        print(f"{path}:{e.lineno}:{e.colno}: invalid JSON: {e.msg}")
        return 1
    try:
        if isinstance(doc, dict) and "faults" in doc:
            FaultPlan(doc.get("faults", []), seed=doc.get("seed", 0))
            kind = f"process fault plan ({len(doc.get('faults', []))} faults)"
        else:
            validate_net_fault_doc(doc)
            kind = "net-fault plan"
    except (ValueError, TypeError) as e:
        print(f"{path}: invalid: {e}")
        return 1
    if verbose:
        print(f"{path}: valid {kind}")
    return 0


# ---------------------------------------------------------------------------
# seeded chaos-smoke scenario (CI entry point)
# ---------------------------------------------------------------------------
def run_smoke(workdir: str, *, seed: int = 0, verbose: bool = True) -> dict:
    """The CI chaos-equivalence scenario: a small pinned grid under a fixed
    FaultPlan (one SIGKILL at a seeded chunk boundary, one corrupt-newest-
    checkpoint, one straggler, one dropped result) must complete via
    retry/backoff and merge bit-identically to the fault-free reference at
    matching lane widths.  Returns a summary dict; raises on mismatch."""
    import jax.numpy as jnp

    from ..core.linalg import eigh_topr
    from ..core.sweep import sdot_sweep, slice_seed_shards
    from ..data.pipeline import eigengap_stream
    from ..streaming.ingest import StreamingIngestor
    from ..streaming.launcher import build_engine, build_schedule, launch_sweep

    d, r, n_nodes, t_outer, t_c = 16, 3, 6, 8, 10
    seeds = list(range(4))
    batch_fn, _, _ = eigengap_stream(d, r, 0.7, seed=seed)
    ing = StreamingIngestor(n_nodes=n_nodes, d=d, batch_fn=batch_fn,
                            batch_size=30)
    ing.ingest(10)
    covs = ing.cov_stack()
    _, q_true = eigh_topr(covs.sum(0), r)
    cases = [{"topology": {"kind": "er", "n": n_nodes, "p": 0.5, "seed": 1},
              "schedule": {"kind": "lin2", "cap": t_c}}]

    # corrupt is pinned to boundary 3 so there IS a newest checkpoint to
    # tear (steps 2 and 4 are on disk by then): the relaunch must fall
    # back to step 2, not start fresh
    plan = FaultPlan(seed=seed, faults=[
        {"kind": "kill", "shard": 0},
        {"kind": "corrupt", "shard": 1, "mode": "truncate", "boundary": 3},
        {"kind": "slow", "shard": 2, "sleep": 0.2},
        {"kind": "drop", "shard": 3},
    ])
    t0 = time.perf_counter()
    sw = launch_sweep(covs=covs, cases=cases, r=r, t_outer=t_outer, t_c=t_c,
                      seeds=seeds, q_true=q_true, workdir=workdir,
                      n_workers=4, n_shards=4, sweep_chunk=2, retries=2,
                      chaos_plan=plan, timeout=600.0)
    chaos_s = time.perf_counter() - t0

    # fault-free reference at MATCHING lane widths: run each shard's seed
    # slice single-process and concatenate, so equality can be bitwise
    engines = [build_engine(c["topology"]) for c in cases]
    schedules = [build_schedule(c["schedule"], t_outer, t_c) for c in cases]
    shard_seeds = slice_seed_shards(seeds, 4)
    parts = [sdot_sweep(covs=covs, engines=engines, schedules=schedules,
                        r=r, t_outer=t_outer, t_c=t_c, seeds=s,
                        q_true=q_true) for s in shard_seeds]
    ref_err = np.concatenate([p.error_traces for p in parts], axis=0)
    ref_q = np.concatenate([np.asarray(p.q) for p in parts], axis=0)
    np.testing.assert_array_equal(np.asarray(sw.error_traces), ref_err)
    np.testing.assert_array_equal(np.asarray(sw.q), ref_q)
    assert list(sw.seeds) == seeds
    ref_ledger = parts[0].ledger
    for p in parts[1:]:
        ref_ledger = ref_ledger.merged(p.ledger)
    assert sw.ledger.p2p == ref_ledger.p2p
    assert sw.ledger.scalars == ref_ledger.scalars

    rep = sw.resume_report or {}
    # the recovery PATHS are part of the acceptance, not just the bits:
    # kill/corrupt/drop each consumed a retry; the torn shard-1 checkpoint
    # (newest step 4, truncated at boundary 3) fell back to step 2
    assert rep["attempts"][0] == 2, rep
    assert rep["attempts"][1] == 2, rep
    assert rep["attempts"][2] == 1, rep
    assert rep["attempts"][3] == 2, rep
    assert rep["worker_resumed_steps"][1] == 2, rep
    summary = {
        "chaos_sweep_s": round(chaos_s, 3),
        "faults": [f["kind"] for f in plan.faults],
        "attempts": rep.get("attempts"),
        "worker_resumed_steps": rep.get("worker_resumed_steps"),
        "bitwise_equal": True,
    }
    if verbose:
        print(json.dumps(summary, indent=2))
    return summary


def main(argv=None) -> int:
    import argparse
    import tempfile

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="run the seeded CI chaos-equivalence scenario")
    ap.add_argument("--validate", metavar="PLAN",
                    help="check a chaos/net-fault plan file and exit "
                         "(prints a line/field diagnostic when malformed)")
    ap.add_argument("--workdir", default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    if args.validate:
        return validate_plan_file(args.validate)
    if not args.smoke:
        ap.error("nothing to do (pass --smoke or --validate)")
    workdir = args.workdir or tempfile.mkdtemp(prefix="chaos_smoke_")
    run_smoke(workdir, seed=args.seed)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
