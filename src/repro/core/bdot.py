"""B-DOT — block-partitioned distributed orthogonal iteration.

The paper's §VI names "randomly block-wise partitioned data, i.e., data
partitioned by both samples and features" as the open direction for data
that is massive in BOTH d and n. This module implements it — a beyond-paper
extension composing the two mechanisms the paper develops:

Nodes form an I x J grid; node (i, j) holds the block X_ij in
R^{d_i x n_j} (feature slab i of sample shard j). Node (i, j) estimates the
rows Q_i of the global eigenspace basis. One outer iteration computes the
OI update  V = X X^T Q  block-wise:

    S_j   = sum_i X_ij^T Q_i          consensus along grid COLUMN j
            (the F-DOT partial-product trick, payload n_j x r)
    W_i   = sum_j X_ij S_j            consensus along grid ROW i
            (the S-DOT sum-of-local-products trick, payload d_i x r)
    Q_i   = distributed CholeskyQR over the row representatives
            (r x r Gram traffic only)

Every consensus runs on a sub-network of the grid (its column or row), so
the scheme inherits S-DOT's Theorem-1-style behaviour on each stage: with
enough consensus rounds per stage the iterate matches centralized OI.
Communication per outer iteration per node is O((n_j + d_i + r) r) — never
a full d x r or d x n object, which is the point of block partitioning.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from .consensus import DenseConsensus
from .fdot import distributed_cholesky_qr
from .linalg import orthonormal_init
from .metrics import CommLedger, subspace_error

__all__ = ["BDOTResult", "bdot"]


@dataclasses.dataclass
class BDOTResult:
    q_rows: List[jnp.ndarray]       # per feature-slab Q_i (d_i x r), consensus
    error_trace: Optional[np.ndarray]
    ledger: CommLedger

    @property
    def q_full(self) -> jnp.ndarray:
        return jnp.concatenate(self.q_rows, axis=0)


def bdot(
    *,
    blocks: Sequence[Sequence[jnp.ndarray]],   # blocks[i][j]: (d_i, n_j)
    col_engines: Sequence[DenseConsensus],
    row_engines: Sequence[DenseConsensus],
    r: int,
    t_outer: int,
    t_c: int = 50,
    q_init: Optional[jnp.ndarray] = None,
    q_true: Optional[jnp.ndarray] = None,
    seed: int = 0,
) -> BDOTResult:
    """Run B-DOT over a simulated I x J node grid.

    ``col_engines[j]`` is the gossip engine over the I nodes of column j
    (they exchange n_j x r partials); ``row_engines[i]`` gossips over the J
    nodes of row i (d_i x r partials). The final QR gossips r x r Grams over
    a column engine (one representative per feature slab; any connected
    overlay works).
    """
    n_rows = len(blocks)
    n_cols = len(blocks[0])
    assert len(col_engines) == n_cols and len(row_engines) == n_rows
    dims = [int(blocks[i][0].shape[0]) for i in range(n_rows)]
    d = sum(dims)

    if q_init is None:
        q_init = orthonormal_init(jax.random.PRNGKey(seed), d, r)
    offs = np.cumsum([0] + dims)
    # every node of row i starts from the same slab Q_i
    q_rows = [q_init[offs[i]:offs[i + 1]] for i in range(n_rows)]

    ledger = CommLedger()
    errs = [] if q_true is not None else None

    for _ in range(t_outer):
        # --- stage 1: per column j, consensus-sum the (n_j x r) partials
        s_cols = []
        for j in range(n_cols):
            z0 = jnp.stack([blocks[i][j].T @ q_rows[i]
                            for i in range(n_rows)])          # (I, n_j, r)
            s = col_engines[j].run_debiased(z0, t_c, ledger)
            s_cols.append(s.mean(0))   # all column members now agree (≈)

        # --- stage 2: per row i, consensus-sum the (d_i x r) expansions
        new_rows = []
        for i in range(n_rows):
            z0 = jnp.stack([blocks[i][j] @ s_cols[j]
                            for j in range(n_cols)])          # (J, d_i, r)
            w = row_engines[i].run_debiased(z0, t_c, ledger)
            new_rows.append(w.mean(0))

        # --- stage 3: distributed CholeskyQR across feature slabs (I nodes)
        q_rows = distributed_cholesky_qr(new_rows, col_engines[0], t_c,
                                         ledger)
        if errs is not None:
            errs.append(float(subspace_error(
                q_true, jnp.concatenate(q_rows, axis=0))))

    return BDOTResult(
        q_rows=q_rows,
        error_trace=np.asarray(errs) if errs is not None else None,
        ledger=ledger,
    )
