"""command-r-35b — GQA, no-bias, 256k vocab
[hf:CohereForAI/c4ai-command-r-v01; unverified]."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="command-r-35b", family="dense",
    n_layers=40, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=22_528, vocab_size=256_000,
    block_pattern=("attn",), rope_theta=1e6,
)
