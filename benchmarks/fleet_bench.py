"""Fleet robustness benchmark: straggler vs heartbeat + work stealing.

The paper's Table-V straggler study shows one slow machine dominating a
distributed sweep's wall clock. This benchmark applies that adversary to
our own launcher (a seeded chaos ``slow`` fault pins a per-chunk sleep on
ONE worker, ~10x its fault-free chunk time) and compares the two
supervision modes end to end:

* **pinned** — the fixed launcher: every shard is pinned to its worker, so
  the merged result is gated on the straggler grinding through all of its
  chunk boundaries. This is the old serial-timeout world: correct, but the
  sweep's wall clock IS the straggler's wall clock.
* **elastic** — lease-based fleet: the straggler's per-chunk sleep blows
  through its lease TTL, a finished worker STEALS the stale lease and
  resumes the shard from the victim's checkpointed sweep-RunState; the
  victim observes the foreign fencing token at its next renewal and backs
  off. The sweep finishes at roughly the fast workers' pace.

Both modes must merge BIT-IDENTICALLY to the per-shard single-process
reference (asserted every run — robustness never buys approximation), so
the only thing being compared is wall clock.

Usage:
    PYTHONPATH=src python -m benchmarks.fleet_bench [--smoke]

Writes BENCH_fleet.json (or .smoke.json) next to the repo root.
"""
from __future__ import annotations

import json
import pathlib
import shutil
import sys
import tempfile
import time

import jax
import numpy as np

from repro.core.sweep import sdot_sweep, slice_seed_shards
from repro.streaming.chaos import FaultPlan
from repro.streaming.launcher import (build_engine, build_schedule,
                                      launch_sweep)

from .common import sample_problem

N, R = 12, 4


def bench_straggler(*, d, t_outer, chunk, n_seeds, sleep, ttl,
                    assert_stolen):
    covs, q_true = sample_problem(d=d, r=R, n_nodes=N, n_per=150, gap=0.7,
                                  seed=0)
    cases = [{"topology": {"kind": "er", "n": N, "p": 0.3, "seed": 1},
              "schedule": {"kind": "lin2", "cap": 50}}]
    seeds = list(range(n_seeds))
    # the straggler model: worker 0 (pinned: shard 0's process; elastic:
    # fleet worker w0) sleeps ``sleep`` seconds at EVERY chunk boundary —
    # a persistently slow machine, not a one-shot glitch
    plan = FaultPlan(seed=0, faults=[
        {"kind": "slow", "worker": 0, "sleep": sleep}])
    n_boundaries = -(-t_outer // chunk)

    common = dict(covs=covs, cases=cases, r=R, t_outer=t_outer, t_c=50,
                  seeds=seeds, q_true=q_true, n_workers=n_seeds,
                  n_shards=n_seeds, sweep_chunk=chunk, retries=1,
                  chaos_plan=plan, timeout=600.0)

    # fixed launcher: shards pinned to workers, supervision waits the
    # straggler out (stall detection off — the straggler heartbeats
    # between sleeps, it is slow, not dead)
    wd = tempfile.mkdtemp(prefix="fleet_pinned_")
    try:
        t0 = time.perf_counter()
        pinned = launch_sweep(workdir=wd, stall_timeout=0.0, **common)
        pinned_s = time.perf_counter() - t0
    finally:
        shutil.rmtree(wd, ignore_errors=True)

    # elastic fleet: the straggler's lease goes stale mid-sleep and a
    # finished worker steals + resumes the shard from its checkpoint
    wd = tempfile.mkdtemp(prefix="fleet_elastic_")
    try:
        t0 = time.perf_counter()
        elastic = launch_sweep(workdir=wd, elastic=True, lease_ttl=ttl,
                               **common)
        elastic_s = time.perf_counter() - t0
    finally:
        shutil.rmtree(wd, ignore_errors=True)

    # bitwise acceptance against the per-shard single-process reference
    # (matching vmap lane widths, so equality is exact, not epsilon)
    engines = [build_engine(c["topology"]) for c in cases]
    schedules = [build_schedule(c["schedule"], t_outer, 50) for c in cases]
    parts = [sdot_sweep(covs=covs, engines=engines, schedules=schedules,
                        r=R, t_outer=t_outer, t_c=50, seeds=s,
                        q_true=q_true)
             for s in slice_seed_shards(seeds, n_seeds)]
    ref = np.concatenate([p.error_traces for p in parts], axis=0)
    np.testing.assert_array_equal(np.asarray(pinned.error_traces), ref)
    np.testing.assert_array_equal(np.asarray(elastic.error_traces), ref)

    stolen = (elastic.resume_report or {}).get("stolen_shards", [])
    if assert_stolen and not stolen:
        raise AssertionError("elastic run finished without a single steal "
                             "— straggler sleep/ttl did not trigger the "
                             "stealing path")
    return {
        "case": f"straggler/d{d}/To{t_outer}x{n_seeds}seeds/"
                f"sleep{sleep}s_x{n_boundaries}",
        "straggler_penalty_s": round(sleep * n_boundaries, 2),
        "pinned_s": round(pinned_s, 2),
        "elastic_s": round(elastic_s, 2),
        "speedup_x": round(pinned_s / elastic_s, 2),
        "stolen_shards": stolen,
        "lease_owners": (elastic.resume_report or {}).get("lease_owners"),
        "bitwise_equal": True,
    }


def run_bench(smoke: bool = False):
    if smoke:
        return [bench_straggler(d=24, t_outer=8, chunk=2, n_seeds=4,
                                sleep=2.0, ttl=0.5, assert_stolen=False)]
    return [bench_straggler(d=48, t_outer=20, chunk=2, n_seeds=4,
                            sleep=1.5, ttl=0.5, assert_stolen=True)]


def main():
    smoke = "--smoke" in sys.argv
    results = run_bench(smoke=smoke)
    out = {
        "bench": "fleet",
        "scale": {"n_nodes": N, "r": R},
        "smoke": smoke,
        "backend": jax.default_backend(),
        "results": results,
    }
    print(json.dumps(out, indent=2))
    name = "BENCH_fleet.smoke.json" if smoke else "BENCH_fleet.json"
    path = pathlib.Path(__file__).resolve().parent.parent / name
    path.write_text(json.dumps(out, indent=2) + "\n")
    print(f"# wrote {path}")
    if not smoke:
        worst = min(r["speedup_x"] for r in results)
        if worst <= 1.0:
            print(f"# WARNING: elastic stealing did not beat the pinned "
                  f"launcher (speedup {worst}x)")
            sys.exit(1)


if __name__ == "__main__":
    main()
