"""Unified-runtime benchmark: chunked-driver overhead across the zoo.

The unified executor runtime (core/runtime.py) gave B-DOT, the five fused
baselines, and the sweep engine chunked-resumable execution through ONE
generic driver. This benchmark prices that generality: a chunked run must
stay within 10% of its monolithic whole-run scan (the chunk programs
enqueue back-to-back with zero per-chunk host sync, so the cost is pure
dispatch + compile-cache lookup).

Measured cases (all through ``common.interleaved_best_of`` — this
container shows +-20% walltime jitter, so variants run in rotating order
and the per-variant best-of-N is reported):

* monolithic vs chunked fused B-DOT (the family that could not checkpoint
  at all before the runtime), with and without atomic async checkpoints;
* monolithic vs chunked DeEPCA (the baseline with a pytree carry);
* monolithic vs chunked ``sdot_sweep`` (the mid-grid-resumable sweep).

Every chunked result is asserted bit-identical to its monolithic twin
before timings are reported.

Usage:
    PYTHONPATH=src python -m benchmarks.runtime_bench [--smoke]
    PYTHONPATH=src python -m benchmarks.run runtime_bench

Writes BENCH_runtime.json (or .smoke.json) next to the repo root.
"""
from __future__ import annotations

import json
import pathlib
import shutil
import sys
import tempfile

import jax
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.core.baselines import deepca
from repro.core.bdot import bdot
from repro.core.consensus import DenseConsensus
from repro.core.sweep import sdot_sweep
from repro.core.topology import complete, erdos_renyi, ring
from repro.data.pipeline import gaussian_eigengap_data, partition_features
from repro.streaming.resume import baseline_chunked, bdot_chunked

from .common import Row, interleaved_best_of, sample_problem

R = 5


def _grid_problem(d, n_samples, rows, cols, r, seed=0):
    x, _, _ = gaussian_eigengap_data(d, n_samples, r, 0.7, seed=seed)
    from repro.core.linalg import eigh_topr

    _, q_true = eigh_topr(x @ x.T / x.shape[1], r)
    slabs = partition_features(x, rows)
    col_splits = np.array_split(np.arange(n_samples), cols)
    blocks = [[slab[:, idx] for idx in col_splits] for slab in slabs]
    col_engines = [DenseConsensus(complete(rows)) for _ in range(cols)]
    row_engines = [DenseConsensus(ring(cols)) for _ in range(rows)]
    return blocks, col_engines, row_engines, q_true


def bench_bdot_chunked(d, n_samples, t_outer, chunk_size, repeats):
    blocks, ce, re_, q_true = _grid_problem(d, n_samples, 3, 2, R)
    kw = dict(blocks=blocks, col_engines=ce, row_engines=re_, r=R,
              t_outer=t_outer, t_c=30, q_true=q_true)
    mono = lambda: bdot(**kw)
    chunked = lambda mgr: bdot_chunked(chunk_size=chunk_size, manager=mgr,
                                       **kw)
    mono()                                           # warmup compile
    chunked(None)
    ckpt_dir = tempfile.mkdtemp(prefix="bench_rt_ckpt_")

    def with_ckpt():
        shutil.rmtree(ckpt_dir, ignore_errors=True)
        return chunked(CheckpointManager(ckpt_dir, keep_last=2))

    sync = lambda out: jax.block_until_ready(out.q_rows[0])
    try:
        best, outs = interleaved_best_of(
            [("mono", mono), ("chunk", lambda: chunked(None))],
            repeats, sync=sync)
        best_c, outs_c = interleaved_best_of([("ckpt", with_ckpt)], repeats,
                                             sync=sync)
        best.update(best_c)
        outs.update(outs_c)
        np.testing.assert_array_equal(outs["mono"].error_trace,
                                      outs["chunk"].error_trace)
        np.testing.assert_array_equal(outs["mono"].error_trace,
                                      outs["ckpt"].error_trace)
    finally:
        shutil.rmtree(ckpt_dir, ignore_errors=True)
    return {
        "case": f"bdot/d{d}/To{t_outer}/chunk{chunk_size}",
        "monolithic_ms": round(best["mono"] * 1e3, 2),
        "chunked_ms": round(best["chunk"] * 1e3, 2),
        "chunked_ckpt_ms": round(best["ckpt"] * 1e3, 2),
        "chunk_overhead_pct": round(
            (best["chunk"] / best["mono"] - 1.0) * 100, 2),
        "ckpt_overhead_pct": round(
            (best["ckpt"] / best["mono"] - 1.0) * 100, 2),
        "final_err": float(outs["mono"].error_trace[-1]),
    }


def bench_baseline_chunked(d, t_outer, chunk_size, repeats):
    n_nodes = 20
    covs, q_true = sample_problem(d=d, r=R, n_nodes=n_nodes, n_per=100,
                                  gap=0.7, seed=0)
    eng = DenseConsensus(erdos_renyi(n_nodes, 0.25, seed=1))
    mono = lambda: deepca(covs, eng, R, t_outer, q_true=q_true)
    chunked = lambda: baseline_chunked(
        "deepca", covs=covs, engine=eng, r=R, t_outer=t_outer,
        q_true=q_true, chunk_size=chunk_size)
    mono()
    chunked()
    sync = lambda out: jax.block_until_ready(
        out.q if hasattr(out, "q") else out[0])
    best, outs = interleaved_best_of(
        [("mono", mono), ("chunk", chunked)], repeats, sync=sync)
    np.testing.assert_array_equal(outs["mono"][1],
                                  outs["chunk"].error_trace)
    return {
        "case": f"deepca/d{d}/To{t_outer}/chunk{chunk_size}",
        "monolithic_ms": round(best["mono"] * 1e3, 2),
        "chunked_ms": round(best["chunk"] * 1e3, 2),
        "chunk_overhead_pct": round(
            (best["chunk"] / best["mono"] - 1.0) * 100, 2),
    }


def bench_sweep_chunked(d, t_outer, n_seeds, chunk_size, repeats):
    n_nodes = 20
    covs, q_true = sample_problem(d=d, r=R, n_nodes=n_nodes, n_per=100,
                                  gap=0.7, seed=0)
    engines = [DenseConsensus(erdos_renyi(n_nodes, 0.25, seed=1)),
               DenseConsensus(ring(n_nodes))]
    seeds = list(range(n_seeds))
    kw = dict(covs=covs, engines=engines, r=R, t_outer=t_outer, t_c=30,
              seeds=seeds, q_true=q_true)
    mono = lambda: sdot_sweep(**kw)
    chunked = lambda: sdot_sweep(chunk_size=chunk_size, **kw)
    mono()
    chunked()
    sync = lambda out: jax.block_until_ready(out.q)
    best, outs = interleaved_best_of(
        [("mono", mono), ("chunk", chunked)], repeats, sync=sync)
    np.testing.assert_array_equal(outs["mono"].error_traces,
                                  outs["chunk"].error_traces)
    return {
        "case": f"sweep/d{d}/To{t_outer}/{n_seeds}seeds/chunk{chunk_size}",
        "monolithic_ms": round(best["mono"] * 1e3, 2),
        "chunked_ms": round(best["chunk"] * 1e3, 2),
        "chunk_overhead_pct": round(
            (best["chunk"] / best["mono"] - 1.0) * 100, 2),
    }


def run_bench(smoke: bool = False):
    if smoke:
        return [
            bench_bdot_chunked(d=24, n_samples=240, t_outer=20,
                               chunk_size=8, repeats=1),
            bench_baseline_chunked(d=24, t_outer=30, chunk_size=10,
                                   repeats=1),
        ]
    # runs sized >= ~0.5 s so per-chunk dispatch cost is integrated over
    # this container's +-20% throttling jitter
    return [
        bench_bdot_chunked(d=240, n_samples=1200, t_outer=150,
                           chunk_size=25, repeats=7),
        bench_baseline_chunked(d=100, t_outer=600, chunk_size=60,
                               repeats=7),
        bench_sweep_chunked(d=80, t_outer=200, n_seeds=8, chunk_size=40,
                            repeats=5),
    ]


def run():
    """benchmarks.run entry point."""
    rows = []
    for rec in run_bench(smoke=False):
        rows.append(Row(
            f"runtime/{rec['case']}", rec["chunked_ms"] * 1e3,
            {"monolithic_ms": rec["monolithic_ms"],
             "overhead_pct": rec["chunk_overhead_pct"]}))
    return rows


def main():
    smoke = "--smoke" in sys.argv
    results = run_bench(smoke=smoke)
    out = {
        "bench": "runtime",
        "smoke": smoke,
        "backend": jax.default_backend(),
        "results": results,
    }
    print(json.dumps(out, indent=2))
    name = "BENCH_runtime.smoke.json" if smoke else "BENCH_runtime.json"
    path = pathlib.Path(__file__).resolve().parent.parent / name
    path.write_text(json.dumps(out, indent=2) + "\n")
    print(f"# wrote {path}")
    if not smoke:
        worst = max(r["chunk_overhead_pct"] for r in results)
        if worst > 10.0:
            print(f"# WARNING: chunked overhead {worst}% above the 10% bar")
            sys.exit(1)


if __name__ == "__main__":
    main()
