"""Unified observability layer: span journal + metrics registry + CLI.

Three pieces, strictly OUT-OF-BAND (host-side file appends only — device
math replays bit-identical with tracing on or off):

* ``journal`` — crash-safe append-only JSONL span/event journals, one per
  process attempt, with a torn-tail-tolerant reader;
* ``registry`` — counters / gauges / bucketed histograms with p50/p99 and
  a Prometheus-style exposition;
* ``cli`` (``python -m repro.obs``) — merge per-process journals into one
  timeline, per-phase duration summaries, text exposition, a plain-text
  Gantt, and a ``forensics`` mode reconstructing a dead worker's last
  spans and attributing every injected chaos fault to the phase it fired
  in.

Process wiring: long-lived components (sweep workers, the launcher, the
serving loop) call ``install(workdir, proc)`` once at startup, which opens
an attempt-scoped journal under ``obs_dir_for(workdir)`` (default
``<workdir>/obs``; override with ``REPRO_OBS_DIR``; disable everything
with ``REPRO_OBS=0``) and a fresh process registry. Library seams
(``core/runtime``, ``checkpoint/manager``, chaos hooks) fetch the current
journal via ``get_journal()`` — a no-op shell unless something installed
one, so bare library calls (tests, benchmarks) stay untraced and pay one
attribute check.
"""
from __future__ import annotations

import os
from typing import Optional

from .journal import (ENV_DIR, ENV_OBS, Journal, Span, journal_files,
                      merge_journals, read_journal)
from .registry import Counter, Gauge, Histogram, MetricsRegistry

__all__ = ["Journal", "Span", "read_journal", "merge_journals",
           "journal_files", "Counter", "Gauge", "Histogram",
           "MetricsRegistry", "get_journal", "set_journal", "metrics",
           "install", "obs_dir_for", "ENV_DIR", "ENV_OBS"]

_journal: Journal = Journal.noop()
_registry: MetricsRegistry = MetricsRegistry()


def get_journal() -> Journal:
    """The process journal (a disabled no-op unless ``install``ed)."""
    return _journal


def set_journal(journal: Journal) -> Journal:
    global _journal
    _journal = journal
    return journal


def metrics() -> MetricsRegistry:
    """The process metrics registry (always usable; reset by ``install``)."""
    return _registry


def obs_dir_for(workdir: str) -> Optional[str]:
    """Where a component rooted at ``workdir`` should journal.

    ``REPRO_OBS=0`` -> None (observability fully off); ``REPRO_OBS_DIR``
    overrides; default ``<workdir>/obs`` — tracing is ON by default for
    workdir-rooted components because the journal is out-of-band and its
    cost is a few atomic line appends per chunk boundary."""
    if os.environ.get(ENV_OBS, "").lower() in ("0", "off", "false"):
        return None
    return os.environ.get(ENV_DIR) or os.path.join(workdir, "obs")


def install(workdir: str, proc: str, **static) -> Journal:
    """Open (and make current) an attempt-scoped journal for this process
    plus a FRESH metrics registry wired into it (span durations feed
    ``span_<name>_seconds`` histograms). Returns the journal; a disabled
    no-op journal when observability is off."""
    global _registry
    _registry = MetricsRegistry()
    obs_dir = obs_dir_for(workdir)
    if obs_dir is None:
        return set_journal(Journal.noop())
    return set_journal(Journal.open(obs_dir, proc, registry=_registry,
                                    **static))
