"""B-DOT — block-partitioned DOT (the paper's §VI future-work direction)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.bdot import bdot
from repro.core.consensus import DenseConsensus
from repro.core.linalg import eigh_topr
from repro.core.topology import complete, erdos_renyi
from repro.data.pipeline import (gaussian_eigengap_data, partition_features,
                                 partition_samples)


def _grid_problem(d=24, r=4, I=4, J=5, n=3000, gap=0.6, seed=0):
    x, _, _ = gaussian_eigengap_data(d, n, r, gap, seed=seed)
    _, q_true = eigh_topr(x @ x.T, r)
    fslabs = partition_features(x, I)
    blocks = [partition_samples(sl, J) for sl in fslabs]
    return x, blocks, q_true


def test_bdot_converges():
    x, blocks, q_true = _grid_problem()
    I, J = len(blocks), len(blocks[0])
    cols = [DenseConsensus(erdos_renyi(I, 0.7, seed=j)) for j in range(J)]
    rows = [DenseConsensus(erdos_renyi(J, 0.7, seed=10 + i)) for i in range(I)]
    res = bdot(blocks=blocks, col_engines=cols, row_engines=rows, r=4,
               t_outer=60, t_c=60, q_true=q_true)
    assert res.error_trace[-1] < 1e-5
    q = res.q_full
    np.testing.assert_allclose(np.asarray(q.T @ q), np.eye(4), atol=1e-4)


def test_bdot_blocks_cover_data():
    x, blocks, _ = _grid_problem()
    rebuilt = jnp.concatenate(
        [jnp.concatenate(row, axis=1) for row in blocks], axis=0)
    np.testing.assert_array_equal(np.asarray(rebuilt), np.asarray(x))


def test_bdot_payloads_are_blockwise():
    """Per-node traffic never includes a full d x r or d x n object."""
    x, blocks, q_true = _grid_problem()
    I, J = len(blocks), len(blocks[0])
    cols = [DenseConsensus(complete(I)) for _ in range(J)]
    rows = [DenseConsensus(complete(J)) for _ in range(I)]
    res = bdot(blocks=blocks, col_engines=cols, row_engines=rows, r=4,
               t_outer=3, t_c=10, q_true=q_true)
    # ledger counts elements actually moved; bound them by the blockwise
    # payload model: per outer iter per stage
    d, n, r = 24, 3000, 4
    n_j, d_i = n // J, d // I
    per_iter_elems = (
        10 * (I * (I - 1)) * n_j * r * J          # stage 1 per column
        + 10 * (J * (J - 1)) * d_i * r * I        # stage 2 per row
        + 2 * 10 * (I * (I - 1)) * r * r          # QR grams (2 passes)
    )
    assert res.ledger.scalars == pytest.approx(3 * per_iter_elems)


def test_bdot_matches_centralized_oi_exact_consensus():
    import jax
    from repro.core.linalg import orthonormal_init
    from repro.core.oi import orthogonal_iteration
    from repro.core.metrics import subspace_error
    x, blocks, q_true = _grid_problem()
    I, J = len(blocks), len(blocks[0])
    cols = [DenseConsensus(complete(I)) for _ in range(J)]
    rows = [DenseConsensus(complete(J)) for _ in range(I)]
    q0 = orthonormal_init(jax.random.PRNGKey(1), 24, 4)
    res = bdot(blocks=blocks, col_engines=cols, row_engines=rows, r=4,
               t_outer=8, t_c=150, q_init=q0)
    q_oi = orthogonal_iteration(x @ x.T, q0, 8)
    assert float(subspace_error(q_oi, res.q_full)) < 1e-5
