"""Unified decoder stack for all assigned architectures.

Layers are organized as ``n_groups`` repeats of the config's block pattern
(e.g. recurrentgemma: ("rglru","rglru","attn")); parameters are *stacked*
over the group axis and the stack is applied with ``lax.scan`` — HLO size is
O(pattern length), not O(n_layers), which keeps 61-layer Kimi-K2 compiles
tractable with 512 SPMD partitions.

Three entry points:
  * forward(params, batch, cfg)              — training / prefill logits
  * init_decode_state(cfg, batch, max_len)   — per-family caches/states
  * decode_step(params, state, tokens, cfg)  — one-token serving step
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from .attention import apply_attn, init_attn
from .layers import embed_lookup, init_dense, init_norm, rms_norm, swiglu_ffn
from .moe import apply_moe, init_moe
from .recurrent import (apply_mlstm, apply_rglru, apply_slstm, init_mlstm,
                        init_rglru, init_slstm)

__all__ = ["init_params", "forward", "init_decode_state", "decode_step",
           "block_has_ffn"]

ATTN_KINDS = ("attn", "swa")


def block_has_ffn(cfg: ModelConfig, kind: str) -> bool:
    if kind in ATTN_KINDS:
        return cfg.moe is not None or cfg.d_ff > 0
    if kind == "rglru":
        return cfg.d_ff > 0
    return False  # mlstm / slstm have internal FFN-equivalents


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------
def _init_block(key, cfg: ModelConfig, kind: str) -> Dict[str, Any]:
    k1, k2, k3 = jax.random.split(key, 3)
    dt = cfg.jnp_dtype
    p: Dict[str, Any] = {"norm1": init_norm(cfg.d_model, dt)}
    if kind in ATTN_KINDS:
        p["mixer"] = init_attn(k1, cfg)
    elif kind == "mlstm":
        p["mixer"] = init_mlstm(k1, cfg)
    elif kind == "slstm":
        p["mixer"] = init_slstm(k1, cfg)
    elif kind == "rglru":
        p["mixer"] = init_rglru(k1, cfg)
    else:
        raise ValueError(f"unknown block kind {kind}")
    if block_has_ffn(cfg, kind):
        p["norm2"] = init_norm(cfg.d_model, dt)
        if cfg.moe is not None and kind in ATTN_KINDS:
            p["ffn"] = init_moe(k2, cfg)
        else:
            ks = jax.random.split(k3, 3)
            p["ffn"] = {
                "w_gate": init_dense(ks[0], cfg.d_model, cfg.d_ff, dt),
                "w_up": init_dense(ks[1], cfg.d_model, cfg.d_ff, dt),
                "w_down": init_dense(ks[2], cfg.d_ff, cfg.d_model, dt),
            }
    return p


def init_params(key, cfg: ModelConfig) -> Dict[str, Any]:
    keys = jax.random.split(key, 4)
    dt = cfg.jnp_dtype
    pattern = cfg.pattern_for_layers()

    def init_group(gkey):
        bkeys = jax.random.split(gkey, len(pattern))
        return {f"blk{i}_{kind}": _init_block(bkeys[i], cfg, kind)
                for i, kind in enumerate(pattern)}

    gkeys = jax.random.split(keys[0], cfg.n_groups)
    groups = jax.vmap(init_group)(gkeys)

    if cfg.frontend == "audio_codec":
        embed = (jax.random.normal(
            keys[1], (cfg.n_codebooks, cfg.vocab_size, cfg.d_model)) * 0.02).astype(dt)
        head = init_dense(keys[2], cfg.d_model, cfg.n_codebooks * cfg.vocab_size, dt)
    else:
        embed = (jax.random.normal(
            keys[1], (cfg.vocab_size, cfg.d_model)) * 0.02).astype(dt)
        head = None if cfg.tie_embeddings else init_dense(
            keys[2], cfg.d_model, cfg.vocab_size, dt)

    params = {
        "embed": embed,
        "groups": groups,
        "final_norm": init_norm(cfg.d_model, dt),
    }
    if head is not None:
        params["lm_head"] = head
    return params


# ---------------------------------------------------------------------------
# forward (train / prefill)
# ---------------------------------------------------------------------------
def _apply_block_full(p, x, cfg: ModelConfig, kind: str, use_pallas: bool,
                      act_specs=None):
    from jax.ad_checkpoint import checkpoint_name
    h = rms_norm(x, p["norm1"], cfg.norm_eps)
    if kind in ATTN_KINDS:
        window = cfg.window if kind == "swa" else None
        out, _ = apply_attn(p["mixer"], h, cfg, window=window,
                            use_pallas=use_pallas, act_specs=act_specs)
    elif kind == "mlstm":
        out, _ = apply_mlstm(p["mixer"], h, cfg)
    elif kind == "slstm":
        out, _ = apply_slstm(p["mixer"], h, cfg)
    elif kind == "rglru":
        out, _ = apply_rglru(p["mixer"], h, cfg)
    # named save points for the selective-remat policy (remat="names"):
    # everything between them (norms, gates, the big FFN intermediate) is
    # recomputed; the mixer and FFN outputs — the tensors whose recompute
    # would re-run TP all-reduces — are saved.
    x = x + checkpoint_name(out, "mixer_out")
    if block_has_ffn(cfg, kind):
        h2 = rms_norm(x, p["norm2"], cfg.norm_eps)
        if cfg.moe is not None and kind in ATTN_KINDS:
            y = apply_moe(p["ffn"], h2, cfg, act_specs=act_specs)
        else:
            f = p["ffn"]
            y = swiglu_ffn(h2, f["w_gate"], f["w_up"], f["w_down"])
        x = x + checkpoint_name(y, "ffn_out")
    return x


def embed_inputs(params, batch: Dict[str, jnp.ndarray], cfg: ModelConfig):
    """Token embedding with optional modality frontend (STUB frontends:
    precomputed patch/frame embeddings arrive via the batch dict).

    ``inputs_embeds`` short-circuits the lookup — used by the PSA train step,
    which performs the gather OUTSIDE its manual-pod shard_map region (the
    XLA SPMD partitioner cannot partition gathers inside shard_map auto
    sub-meshes at scale; measured CHECK-crash at 512 devices)."""
    if "inputs_embeds" in batch:
        return batch["inputs_embeds"]
    tokens = batch["tokens"]
    if cfg.frontend == "audio_codec":
        # tokens: (b, s, K); sum codebook embeddings
        x = sum(embed_lookup(params["embed"][k], tokens[..., k])
                for k in range(cfg.n_codebooks))
    else:
        x = embed_lookup(params["embed"], tokens)
    if cfg.frontend == "vlm_patches" and "patch_embeds" in batch:
        # splice precomputed image-patch embeddings over the prefix positions
        npfx = cfg.n_prefix_tokens
        pe = batch["patch_embeds"].astype(x.dtype)
        x = jnp.concatenate([pe, x[:, npfx:]], axis=1)
    return x


def _constrain(x, spec):
    if spec is None:
        return x
    return jax.lax.with_sharding_constraint(x, spec)


def forward(params, batch: Dict[str, jnp.ndarray], cfg: ModelConfig, *,
            use_pallas: bool = False, remat: bool = True,
            unroll_layers: bool = False, act_specs=None) -> jnp.ndarray:
    """Returns logits (b, s, V) (audio: (b, s, K, V)).

    ``unroll_layers=True`` replaces the layer-group scan with a Python loop —
    used by the dry-run so HLO cost/collective analysis sees every layer
    (XLA cost_analysis counts while-loop bodies once).

    ``act_specs`` (sharding.activation_specs) pins the residual stream and
    the logits to their intended shardings at every group boundary — without
    it the SPMD partitioner inserts per-layer activation all-gathers
    (EXPERIMENTS.md §Perf iteration 1).

    ``remat``: True = full per-group remat (minimum HBM, +~33% FLOPs and the
    TP all-reduces re-run in backward); "names" = selective (save mixer/FFN
    outputs, recompute only the cheap elementwise span — no collective is
    re-run); False = save everything.
    """
    act = act_specs["act"] if act_specs else None
    x = _constrain(embed_inputs(params, batch, cfg), act)
    pattern = cfg.pattern_for_layers()

    def group_body(x, gparams):
        for i, kind in enumerate(pattern):
            x = _apply_block_full(gparams[f"blk{i}_{kind}"], x, cfg, kind,
                                  use_pallas, act_specs=act_specs)
        return _constrain(x, act), None

    if remat == "names":
        policy = jax.checkpoint_policies.save_only_these_names(
            "mixer_out", "ffn_out")
        body = jax.checkpoint(group_body, policy=policy)
    elif remat:
        body = jax.checkpoint(group_body)
    else:
        body = group_body
    if unroll_layers:
        for g in range(cfg.n_groups):
            gp = jax.tree.map(lambda l: l[g], params["groups"])
            x, _ = body(x, gp)
    else:
        x, _ = jax.lax.scan(body, x, params["groups"])
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)

    head = params.get("lm_head")
    if head is None:  # tied
        emb = params["embed"]
        logits = x @ emb.T if cfg.frontend != "audio_codec" else None
    else:
        logits = x @ head
    if cfg.frontend == "audio_codec":
        b, s, _ = x.shape
        logits = logits.reshape(b, s, cfg.n_codebooks, cfg.vocab_size)
    if act_specs:
        logits = _constrain(logits, act_specs["logits"])
    return logits


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------
def init_decode_state(cfg: ModelConfig, batch: int, max_len: int) -> Dict[str, Any]:
    """Per-pattern-position stacked caches/states + the step counter."""
    from .attention import init_kv_cache
    from .recurrent import init_mlstm_state, init_rglru_state, init_slstm_state

    state: Dict[str, Any] = {"index": jnp.zeros((), jnp.int32)}
    caches = {}
    for i, kind in enumerate(cfg.pattern_for_layers()):
        name = f"blk{i}_{kind}"
        if kind == "attn":
            caches[name] = init_kv_cache(cfg, batch, max_len, cfg.n_groups)
        elif kind == "swa":
            wlen = min(cfg.window or max_len, max_len)
            caches[name] = init_kv_cache(cfg, batch, wlen, cfg.n_groups)
        elif kind == "mlstm":
            caches[name] = init_mlstm_state(cfg, batch, cfg.n_groups)
        elif kind == "slstm":
            caches[name] = init_slstm_state(cfg, batch, cfg.n_groups)
        elif kind == "rglru":
            caches[name] = init_rglru_state(cfg, batch, cfg.n_groups)
    state["caches"] = caches
    return state


def _apply_block_decode(p, x, cfg: ModelConfig, kind: str, cache, index):
    h = rms_norm(x, p["norm1"], cfg.norm_eps)
    if kind in ATTN_KINDS:
        out, new_cache = apply_attn(p["mixer"], h, cfg,
                                    window=cfg.window if kind == "swa" else None,
                                    cache=cache, cache_index=index)
    elif kind == "mlstm":
        out, new_cache = apply_mlstm(p["mixer"], h, cfg, state=cache)
    elif kind == "slstm":
        out, new_cache = apply_slstm(p["mixer"], h, cfg, state=cache)
    elif kind == "rglru":
        out, new_cache = apply_rglru(p["mixer"], h, cfg, state=cache)
    x = x + out
    if block_has_ffn(cfg, kind):
        h2 = rms_norm(x, p["norm2"], cfg.norm_eps)
        if cfg.moe is not None and kind in ATTN_KINDS:
            x = x + apply_moe(p["ffn"], h2, cfg)
        else:
            f = p["ffn"]
            x = x + swiglu_ffn(h2, f["w_gate"], f["w_up"], f["w_down"])
    return x, new_cache


def decode_step(params, state, tokens: jnp.ndarray, cfg: ModelConfig, *,
                unroll_layers: bool = False, act_specs=None):
    """One serving step. tokens: (b, 1) (audio: (b, 1, K)).

    Returns (logits, new_state). The KV/recurrent caches advance by one.
    """
    act = act_specs["act"] if act_specs else None
    index = state["index"]
    x = _constrain(embed_inputs(params, {"tokens": tokens}, cfg), act)
    pattern = cfg.pattern_for_layers()

    def group_body(x, scans):
        gparams, gcaches = scans
        new_caches = {}
        for i, kind in enumerate(pattern):
            name = f"blk{i}_{kind}"
            x, nc = _apply_block_decode(
                gparams[name], x, cfg, kind, gcaches[name], index)
            new_caches[name] = nc
        return x, new_caches

    if unroll_layers:
        outs = []
        for g in range(cfg.n_groups):
            gp = jax.tree.map(lambda l: l[g], params["groups"])
            gc = jax.tree.map(lambda l: l[g], state["caches"])
            x, nc = group_body(x, (gp, gc))
            outs.append(nc)
        new_caches = jax.tree.map(lambda *ls: jnp.stack(ls), *outs)
    else:
        x, new_caches = jax.lax.scan(group_body, x, (params["groups"], state["caches"]))
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = params.get("lm_head")
    logits = x @ (head if head is not None else params["embed"].T)
    if cfg.frontend == "audio_codec":
        b, s, _ = x.shape
        logits = logits.reshape(b, s, cfg.n_codebooks, cfg.vocab_size)
    if act_specs:
        logits = _constrain(logits, act_specs["logits"])
    return logits, {"index": index + 1, "caches": new_caches}
