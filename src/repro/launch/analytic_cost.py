"""Analytic per-step FLOP / HBM-byte model for every (arch x shape) cell.

XLA's cost_analysis counts while-loop bodies once, so any scan-based cost is
unusable as a roofline numerator. Matmul FLOPs, however, are exactly
enumerable from the model code — this module walks the same block structure
as models/transformer.py and counts:

  * FLOPs: 2mnk per matmul (fwd), x3 for backward (dgrad+wgrad), +1 fwd for
    full remat; attention scores/av; recurrences.
  * HBM bytes: weights traffic (streamed once per pass, ZeRO all-gather
    included under its collective term, not here), activations r/w,
    optimizer state update traffic, KV/state cache traffic for decode.

All numbers are GLOBAL per step; divide by chips for per-device terms
(valid because every sharded dim divides evenly or is replicated — the
replication waste is reported separately by the dry-run HLO numbers).
"""
from __future__ import annotations

from typing import Dict

from ..configs.base import ModelConfig, ShapeConfig
from ..models.recurrent import _mlstm_hd, _slstm_hd, mlstm_heads

__all__ = ["analytic_cost", "straggler_slowdown"]


def straggler_slowdown(*, n_nodes: int, t_step: float, delay: float,
                       synchronous: bool = True) -> float:
    """Expected wall time of one outer iteration with one random straggler.

    The paper's Table V setting: a bulk-synchronous network where every
    iteration one randomly-chosen node sleeps ``delay`` seconds. Synchronous
    gossip blocks on the slowest rank, so the whole network pays the delay
    every iteration; an asynchronous network would amortize it (each node is
    the straggler only 1/N of the time).
    """
    if synchronous:
        return t_step + delay
    return t_step + delay / n_nodes


def _attn_block_flops(cfg: ModelConfig, t: int, s_ctx: int, window, decode: bool):
    """Forward FLOPs of one attention block on t tokens with context s_ctx."""
    d, hd = cfg.d_model, cfg.hd
    nq, nkv = cfg.n_heads, cfg.n_kv_heads
    proj = 2 * t * d * (nq * hd) + 2 * 2 * t * d * (nkv * hd) + 2 * t * (nq * hd) * d
    ctx = min(window, s_ctx) if window else s_ctx
    if decode:
        att = 2 * t * nq * hd * ctx * 2          # qk + av over the cache
    else:
        # causal: each token attends to ~min(pos, window) keys; average ctx/2
        # (full) or ~window (swa, once past the window)
        if window and s_ctx > window:
            avg = window
        else:
            avg = ctx / 2
        att = 2 * t * nq * hd * avg * 2
    return proj + att


def _ffn_flops(cfg: ModelConfig, t: int):
    if cfg.moe is not None:
        m = cfg.moe
        act = 3 * 2 * t * cfg.d_model * m.d_expert * (m.top_k + m.n_shared_experts)
        router = 2 * t * cfg.d_model * m.n_experts
        return act + router
    if cfg.d_ff > 0:
        return 3 * 2 * t * cfg.d_model * cfg.d_ff
    return 0


def _mlstm_flops(cfg: ModelConfig, t: int, decode: bool):
    d = cfg.d_model
    up = 2 * d
    h, hd = mlstm_heads(cfg), _mlstm_hd(cfg)
    proj = 2 * t * d * up * 2 + 2 * t * up * d      # up, gate, down
    qkv = 3 * 2 * t * h * hd * hd                    # block-diag per head
    if decode:
        state = t * h * hd * hd * 4                  # kv outer + q.C
    else:
        L = min(cfg.mlstm_chunk, t)
        # intra-chunk quadratic + state update per chunk
        state = 2 * t * h * hd * L * 2 + 2 * t * h * hd * hd * 2
    return proj + qkv + state


def _slstm_flops(cfg: ModelConfig, t: int):
    d = cfg.d_model
    hd = _slstm_hd(d)
    f_up = 4 * d // 3
    gates = 2 * t * d * 4 * d + 2 * t * d * 4 * hd   # input + block-diag recur
    ffn = 2 * t * d * 2 * f_up + 2 * t * f_up * d
    return gates + ffn + 20 * t * d                  # elementwise cell


def _rglru_flops(cfg: ModelConfig, t: int):
    d = cfg.d_model
    proj = 2 * t * d * d * 4                         # in, gate_in, rgate+igate
    out = 2 * t * d * d
    conv = 8 * t * d
    scan = 12 * t * d
    return proj + out + conv + scan


def _head_embed_flops(cfg: ModelConfig, t: int):
    v = cfg.vocab_size * (cfg.n_codebooks if cfg.frontend == "audio_codec" else 1)
    return 2 * t * cfg.d_model * v                   # lm head (embed is gather)


def analytic_cost(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, float]:
    kind = shape.kind
    decode = kind == "decode"
    t = shape.global_batch if decode else shape.tokens
    s_ctx = shape.seq_len

    per_layer = 0.0
    for blk in cfg.pattern_for_layers():
        if blk in ("attn", "swa"):
            w = cfg.window if blk == "swa" else None
            per_layer += _attn_block_flops(cfg, t, s_ctx, w, decode)
            per_layer += _ffn_flops(cfg, t)
        elif blk == "mlstm":
            per_layer += _mlstm_flops(cfg, t, decode)
        elif blk == "slstm":
            per_layer += _slstm_flops(cfg, t)
        elif blk == "rglru":
            per_layer += _rglru_flops(cfg, t)
            if cfg.d_ff > 0:
                per_layer += _ffn_flops(cfg, t)
    fwd = per_layer * cfg.n_groups + _head_embed_flops(cfg, t)

    if kind == "train":
        flops = fwd * (3.0 + 1.0)        # bwd = 2x fwd, +1 fwd remat
    else:
        flops = fwd

    # ---- HBM bytes (global) ----
    pbytes = cfg.jnp_dtype.itemsize
    n_params = cfg.param_count()
    n_active = cfg.active_param_count()
    d = cfg.d_model
    act_unit = t * d * pbytes            # one activation tensor
    n_blocks = cfg.n_layers
    if kind == "train":
        # weights: fwd + bwd + remat reads, wgrad writes; adam: read m,v,p,g
        # write m,v,p (fp32 moments => x2 factor on moment traffic)
        wbytes = n_params * pbytes * 3 + n_params * 4 * 6
        abytes = act_unit * n_blocks * 8         # saved + recomputed + grads
        cbytes = 0.0
    elif kind == "prefill":
        wbytes = n_params * pbytes
        abytes = act_unit * n_blocks * 4
        cbytes = 0.0
    else:
        wbytes = n_active * pbytes               # every weight read once
        abytes = act_unit * n_blocks * 4
        cbytes = _cache_bytes(cfg, shape)
    return {
        "flops": float(flops),
        "hbm_bytes": float(wbytes + abytes + cbytes),
        "weight_bytes": float(wbytes),
        "cache_bytes": float(cbytes),
    }


def _cache_bytes(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """Decode: KV/state cache read+write traffic per step (global)."""
    b = shape.global_batch
    total = 0.0
    pb = cfg.jnp_dtype.itemsize
    for blk in cfg.pattern_for_layers():
        if blk == "attn":
            total += 2 * b * cfg.n_kv_heads * cfg.hd * shape.seq_len * pb  # read K,V
        elif blk == "swa":
            w = min(cfg.window or shape.seq_len, shape.seq_len)
            total += 2 * b * cfg.n_kv_heads * cfg.hd * w * pb
        elif blk == "mlstm":
            h, hd = mlstm_heads(cfg), _mlstm_hd(cfg)
            total += 2 * b * h * hd * hd * 4                    # read+write C
        elif blk == "slstm":
            total += 6 * b * cfg.d_model * 4
        elif blk == "rglru":
            total += 2 * b * cfg.d_model * 4
    return total * cfg.n_groups
