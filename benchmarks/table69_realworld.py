"""Tables VI-IX — real-world datasets (MNIST / CIFAR-10 / LFW / ImageNet).

This container is offline, so the raw datasets are replaced by
*spectrum-matched synthetic stand-ins*: same d, per-node n_i, N, r; a
power-law covariance spectrum fitted to natural-image decay (see
data/pipeline.spectrum_matched_stream). What is validated:

  * P2P counts — exact (they depend only on topology x schedule, not data);
  * the comm/convergence trade-off shape (SA-DOT cheaper, same floor).

Since PR 4 the rows exercise the **streaming subsystem**: each dataset's
samples arrive as stateless-seeded micro-batches through
``streaming/ingest.StreamingIngestor`` (exact per-node ``CovSketch``), the
way a production deployment would build the cov stack — no node ever holds
its full sample block. Ingest and solve walltime are reported separately
(``ingest_ms`` vs the row's solve time); the paper's own profiling
(Elgamal & Hefeeda) says ingestion dominates at scale, and these rows
now measure that split directly.

The LFW and ImageNet rows use the paper's reduced per-node sample counts.
d is kept at the dataset's true dimension; n_i is scaled down ~4x where the
full covariance stack would be slow on this CPU container (noted per row —
P2P columns are unaffected).
"""
from __future__ import annotations

import jax

from repro.core.consensus import DenseConsensus, consensus_schedule
from repro.core.linalg import eigh_topr
from repro.core.sdot import sdot
from repro.core.topology import erdos_renyi
from repro.data.pipeline import spectrum_matched_stream
from repro.streaming.ingest import StreamingIngestor

from .common import Row, timed

# dataset stand-ins: (d, n_total, default r)
DATASETS = {
    "mnist": (784, 12_000, 5),
    "cifar10": (1024, 12_000, 5),
    "lfw": (2914, 6_000, 7),
    "imagenet": (1024, 12_000, 5),
}

CASES = [
    # (dataset, N, p, r, T_o, schedules)
    ("mnist", 20, 0.25, 5, 100, ("t+1", "2t+1", "50")),
    ("mnist", 100, 0.05, 5, 50, ("t+1", "2t+1", "50")),
    ("cifar10", 20, 0.25, 7, 100, ("t+1", "2t+1", "50")),
    ("lfw", 20, 0.25, 7, 60, ("t+1", "50")),
    ("imagenet", 20, 0.25, 5, 100, ("t+1", "2t+1", "50")),
    ("imagenet", 100, 0.05, 5, 50, ("t+1", "50")),
]

_SCHED = {"t+1": ("lin1", 50), "2t+1": ("lin2", 50), "50": ("const", None)}

N_BATCHES = 20   # micro-batches per dataset stream


def _ingest(ds: str, n_nodes: int):
    """Stream the dataset stand-in into per-node covariance sketches."""
    d, n_total, _ = DATASETS[ds]
    batch = spectrum_matched_stream(d, seed=0)
    ingestor = StreamingIngestor(n_nodes=n_nodes, d=d, batch_fn=batch,
                                 batch_size=n_total // N_BATCHES)
    ingestor.ingest(N_BATCHES)
    # the updates dispatch asynchronously — block so ingest_ms is walltime,
    # not dispatch time (the solve phase must not inherit ingest work)
    jax.block_until_ready(ingestor.sketch.second_moment)
    return ingestor


def run():
    rows = []
    cache = {}
    for ds, n_nodes, p, r, t_o, schedules in CASES:
        d, n_total, _ = DATASETS[ds]
        key = (ds, n_nodes)
        if key not in cache:
            ingestor, ingest_us = timed(_ingest, ds, n_nodes)
            covs = ingestor.cov_stack()
            _, q_true = eigh_topr(covs.sum(0), max(r, 7))
            cache[key] = (covs, q_true, ingest_us)
        covs, q_true_full, ingest_us = cache[key]
        q_true = q_true_full[:, :r]
        g = erdos_renyi(n_nodes, p, seed=1)
        eng = DenseConsensus(g)
        for label in schedules:
            kind, cap = _SCHED[label]
            sched = consensus_schedule(kind, t_o, t_max=50, cap=cap)
            res, us = timed(sdot, covs=covs, engine=eng, r=r, t_outer=t_o,
                            schedule=sched, q_true=q_true)
            rows.append(Row(
                f"table69/{ds}/N{n_nodes}/r{r}/Tc={label}", us,
                {"p2p_k": round(res.ledger.per_node_p2p(n_nodes) / 1e3, 2),
                 "final_err": f"{res.error_trace[-1]:.2e}",
                 "ingest_ms": round(ingest_us / 1e3, 1),
                 "solve_ms": round(us / 1e3, 1),
                 "d": d, "T_o": t_o}))
    return rows
