"""Fused vs eager S-DOT/SA-DOT executor benchmark (Table-III/IV scale).

Measures the tentpole win: one jitted lax.scan for a whole run vs the eager
per-outer-iteration dispatch chain. Reports walltime (post-warmup for the
fused path; the eager path has no meaningful warmup — SA-DOT budgets change
every iteration, so its inner-gossip jit recompiles per distinct T_c) and
host-interaction counts (dispatches + syncs per run, counted analytically
from the execution structure: the eager loop issues one gossip dispatch, one
host matrix_power, one ledger Python loop and one float() sync per outer
iteration; the fused path issues one dispatch and one trailing sync total).

Usage:
    PYTHONPATH=src python -m benchmarks.sdot_fused [--smoke]
    PYTHONPATH=src python -m benchmarks.run sdot_fused

Writes BENCH_sdot_fused.json next to the repo root (acceptance artifact).
"""
from __future__ import annotations

import json
import pathlib
import sys
import time

import jax
import numpy as np

from repro.core.consensus import DenseConsensus, consensus_schedule
from repro.core.sdot import sdot
from repro.core.topology import ring, star

from .common import Row, sample_problem

N, R, D = 20, 5, 20


def _time(fn, repeats=1):
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn()
        jax.block_until_ready(out.q_nodes)
        best = min(best, time.perf_counter() - t0)
    return best, out


def bench_case(label, engine, covs, q_true, schedule, t_outer, repeats):
    run = lambda fused: sdot(covs=covs, engine=engine, r=R, t_outer=t_outer,
                             schedule=schedule, q_true=q_true, fused=fused)
    _time(lambda: run(True))                      # warmup: compile fused
    fused_s, fres = _time(lambda: run(True), repeats)
    eager_s, eres = _time(lambda: run(False))     # eager: 1 rep (it's slow)
    np.testing.assert_allclose(fres.error_trace, eres.error_trace, rtol=1e-4,
                               atol=1e-6)         # same math, always
    return {
        "case": label,
        "t_outer": t_outer,
        "fused_ms": round(fused_s * 1e3, 2),
        "eager_ms": round(eager_s * 1e3, 2),
        "speedup": round(eager_s / fused_s, 1),
        # host interactions per run (see module docstring)
        "eager_host_interactions": 4 * t_outer,
        "fused_host_interactions": 2,
        "final_err": float(fres.error_trace[-1]),
    }


def run_bench(smoke: bool = False):
    t_outer = 20 if smoke else 100
    repeats = 1 if smoke else 3
    covs, q_true = sample_problem(d=D, r=R, n_nodes=N, n_per=500, gap=0.7,
                                  seed=0)
    cases = [
        ("ring/sdot/Tc=50", DenseConsensus(ring(N)),
         consensus_schedule("const", t_outer, t_max=50)),
        ("ring/sadot/2t+1cap50", DenseConsensus(ring(N)),
         consensus_schedule("lin2", t_outer, cap=50)),
        ("star/sadot/2t+1cap50", DenseConsensus(star(N)),
         consensus_schedule("lin2", t_outer, cap=50)),
    ]
    return [bench_case(label, eng, covs, q_true, sched, t_outer, repeats)
            for label, eng, sched in cases]


def run():
    """benchmarks.run entry point."""
    rows = []
    for rec in run_bench(smoke=False):
        rows.append(Row(
            f"sdot_fused/{rec['case']}", rec["fused_ms"] * 1e3,
            {"eager_ms": rec["eager_ms"], "speedup": rec["speedup"],
             "final_err": f"{rec['final_err']:.2e}"}))
    return rows


def main():
    smoke = "--smoke" in sys.argv
    results = run_bench(smoke=smoke)
    out = {
        "bench": "sdot_fused",
        "scale": {"n_nodes": N, "d": D, "r": R},
        "smoke": smoke,
        "backend": jax.default_backend(),
        "results": results,
    }
    print(json.dumps(out, indent=2))
    # smoke results go to a sibling file so they never clobber the committed
    # full-scale artifact
    name = "BENCH_sdot_fused.smoke.json" if smoke else "BENCH_sdot_fused.json"
    path = pathlib.Path(__file__).resolve().parent.parent / name
    path.write_text(json.dumps(out, indent=2) + "\n")
    print(f"# wrote {path}")
    worst = min(r["speedup"] for r in results)
    if not smoke and worst < 5.0:
        print(f"# WARNING: worst-case speedup {worst}x below the 5x bar")
        sys.exit(1)


if __name__ == "__main__":
    main()
