"""Numerical linear algebra helpers tuned for the MXU.

CholeskyQR2 replaces Householder QR everywhere in this codebase: it consists
of three matmuls + one tiny (r x r) Cholesky, which maps to the TPU MXU
whereas Householder is sequential. Two passes restore the orthogonality lost
to squaring the condition number.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["cholesky_qr", "cholesky_qr2", "orthonormal_init", "eigh_topr"]


def cholesky_qr(v: jnp.ndarray, eps: float = 0.0):
    """One CholeskyQR pass: V = Q R with Q^T Q ~= I.

    Gram is computed in float32 at minimum for stability.
    """
    acc = jnp.promote_types(v.dtype, jnp.float32)
    g = (v.astype(acc).T @ v.astype(acc))
    if eps:
        g = g + eps * jnp.eye(g.shape[0], dtype=acc)
    r = jnp.linalg.cholesky(g).T  # upper triangular
    q = jax.scipy.linalg.solve_triangular(r.T, v.astype(acc).T, lower=True).T
    return q.astype(v.dtype), r.astype(v.dtype)


def cholesky_qr2(v: jnp.ndarray, eps: float = 1e-12):
    """CholeskyQR2: two passes; orthogonality error ~ machine eps."""
    q1, r1 = cholesky_qr(v, eps=eps)
    q2, r2 = cholesky_qr(q1, eps=0.0)
    return q2, r2 @ r1


def orthonormal_init(key, d: int, r: int, dtype=jnp.float32) -> jnp.ndarray:
    """Random d x r matrix with orthonormal columns (Q_init of Alg. 1/2)."""
    a = jax.random.normal(key, (d, r), dtype=jnp.float32)
    q, _ = jnp.linalg.qr(a)
    return q.astype(dtype)


def eigh_topr(m: jnp.ndarray, r: int):
    """Top-r eigenpairs of a symmetric matrix (ground truth for tests)."""
    vals, vecs = jnp.linalg.eigh(m)
    order = jnp.argsort(vals)[::-1]
    vals = vals[order][:r]
    vecs = vecs[:, order][:, :r]
    return vals, vecs
