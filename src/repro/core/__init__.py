"""Core distributed-PSA library (the paper's contribution).

Public API:
    topology     — graphs + doubly-stochastic weights + mixing time
    consensus    — gossip engines (dense simulation / SPMD shard_map)
    oi           — centralized orthogonal iteration
    sdot         — S-DOT and SA-DOT (sample-partitioned)
    fdot         — F-DOT + distributed CholeskyQR (feature-partitioned)
    bdot         — B-DOT (block-partitioned; beyond-paper, the paper's §VI)
    baselines    — SeqPM, SeqDistPM, DSA, DPGD, DeEPCA, d-PM
    metrics      — subspace error (paper eq. 11), comm ledgers
    runtime      — unified executor runtime (Program protocol + the
                   monolithic / chunked / sweep drivers)
    sweep        — vmapped Monte-Carlo sweeps over the fused executors
    sweep_utils  — shared ragged-N padding (identity nodes / zero slabs)
"""
from . import baselines, bdot, consensus, fdot, linalg, metrics, oi, runtime, sdot, sweep, sweep_utils, topology  # noqa: F401
from .bdot import bdot as run_bdot  # noqa: F401
from .consensus import DenseConsensus, SpmdConsensus, consensus_schedule  # noqa: F401
from .fdot import fdot as run_fdot  # noqa: F401
from .linalg import cholesky_qr2, orthonormal_init  # noqa: F401
from .metrics import CommLedger, subspace_error  # noqa: F401
from .oi import orthogonal_iteration  # noqa: F401
from .sdot import sadot as run_sadot, sdot as run_sdot, sdot_spmd  # noqa: F401
from .sweep import SweepResult, baseline_sweep, fdot_sweep, sdot_sweep  # noqa: F401
from .topology import Graph, erdos_renyi, local_degree_weights, mixing_time, ring, star  # noqa: F401
