"""SPMD tests (shard_map / pjit) — run in subprocesses so the placeholder
device count never leaks into the other tests' jax backend."""
import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_spmd(script: str, n_devices: int = 8, timeout: int = 420) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(script)],
                       capture_output=True, text=True, timeout=timeout,
                       env=env)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    return r.stdout


def test_spmd_consensus_matches_dense_ring():
    run_spmd("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import Mesh
        from repro.core.topology import ring
        from repro.core.consensus import DenseConsensus, SpmdConsensus
        n = 8
        mesh = Mesh(np.array(jax.devices()), ("nodes",))
        g = ring(n)
        dense = DenseConsensus(g)
        spmd = SpmdConsensus(mesh, "nodes", graph=g)
        z0 = jnp.asarray(np.random.default_rng(0).standard_normal((n, 6, 3)),
                         jnp.float32)
        for t_c in (1, 5, 20):
            want = dense.run_debiased(z0, t_c)
            got = spmd.build_debiased_sum(t_c)(z0)
            np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                       rtol=1e-5, atol=1e-5)
        print("ring OK")
    """)


def test_spmd_consensus_matches_dense_general_graph():
    run_spmd("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import Mesh
        from repro.core.topology import erdos_renyi
        from repro.core.consensus import DenseConsensus, SpmdConsensus
        n = 8
        mesh = Mesh(np.array(jax.devices()), ("nodes",))
        g = erdos_renyi(n, 0.5, seed=3)
        dense = DenseConsensus(g)
        spmd = SpmdConsensus(mesh, "nodes", graph=g)
        z0 = jnp.asarray(np.random.default_rng(1).standard_normal((n, 5, 2)),
                         jnp.float32)
        want = dense.run_debiased(z0, 12)
        got = spmd.build_debiased_sum(12)(z0)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)
        print("general OK")
    """)


def test_spmd_fused_sdot_matches_dense_fused():
    """Whole-run SPMD S-DOT (one shard_map program: masked collective gossip
    + device debias table inside the outer scan) == the fused DenseConsensus
    executor, on a ring and a general graph, with a varying SA-DOT budget."""
    run_spmd("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import Mesh
        from repro.core.topology import erdos_renyi, ring
        from repro.core.consensus import (DenseConsensus, SpmdConsensus,
                                          consensus_schedule)
        from repro.core.sdot import sdot, sdot_spmd
        from repro.core.linalg import eigh_topr
        from repro.data.pipeline import (gaussian_eigengap_data,
                                         partition_samples)
        n, d, r = 8, 16, 3
        x, _, _ = gaussian_eigengap_data(d, n * 400, r, 0.7, seed=0)
        covs = jnp.stack([b @ b.T / b.shape[1]
                          for b in partition_samples(x, n)])
        _, q_true = eigh_topr(covs.sum(0), r)
        mesh = Mesh(np.array(jax.devices()), ("nodes",))
        sched = consensus_schedule("lin2", 12, cap=30)
        for g in (ring(n), erdos_renyi(n, 0.5, seed=3)):
            want = sdot(covs=covs, engine=DenseConsensus(g), r=r, t_outer=12,
                        schedule=sched, q_true=q_true)
            got = sdot_spmd(covs=covs, engine=SpmdConsensus(mesh, "nodes",
                                                            graph=g),
                            r=r, t_outer=12, schedule=sched, q_true=q_true)
            np.testing.assert_allclose(got.error_trace, want.error_trace,
                                       rtol=1e-4, atol=1e-6)
            np.testing.assert_allclose(np.asarray(got.q_nodes),
                                       np.asarray(want.q_nodes), rtol=1e-4,
                                       atol=1e-5)
            assert got.ledger.p2p == want.ledger.p2p
            assert got.ledger.scalars == want.ledger.scalars
        print("spmd fused OK")
    """)


def test_two_level_reduce_exactness():
    """psum intra + enough gossip rounds inter == the true global sum."""
    run_spmd("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import Mesh, PartitionSpec as P
        from repro.core.compat import shard_map
        from repro.core.topology import ring
        from repro.core.consensus import SpmdConsensus, two_level_reduce
        devs = np.array(jax.devices()).reshape(4, 2)
        mesh = Mesh(devs, ("pod", "data"))
        spmd = SpmdConsensus(mesh, "pod", graph=ring(4))
        z = jnp.asarray(np.random.default_rng(0).standard_normal((4, 2, 5, 3)),
                        jnp.float32)
        def f(zloc):
            return two_level_reduce(zloc[0, 0], intra_axis="data",
                                    inter=spmd, t_c=60)[None, None]
        out = jax.jit(shard_map(f, mesh=mesh,
                                in_specs=(P("pod", "data", None, None),),
                                out_specs=P("pod", "data", None, None)))(z)
        want = z.sum(axis=(0, 1))
        for i in range(4):
            for j in range(2):
                np.testing.assert_allclose(np.asarray(out[i, j]),
                                           np.asarray(want), rtol=1e-4,
                                           atol=1e-4)
        print("two-level OK")
    """)


def test_psa_train_step_multipod_runs():
    """The paper-integrated train step executes on a 2-pod test mesh and the
    loss/grad-norm stay finite; PSA state keeps its structure."""
    run_spmd("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_arch, reduced_config
        from repro.configs.base import PSAConfig
        from repro.launch.mesh import make_test_mesh
        from repro.models.transformer import init_params
        from repro.optim.adamw import AdamWConfig, adamw_init
        from repro.optim.psa_compress import psa_init
        from repro.train.step import make_psa_train_step
        from repro.data.pipeline import make_lm_batch

        cfg = reduced_config(get_arch("qwen2-7b"))
        mesh = make_test_mesh(multi_pod=True)
        psa = PSAConfig(rank=4, oi_iters=1, gossip_rounds=2)
        opt = AdamWConfig(warmup_steps=1)
        params = init_params(jax.random.PRNGKey(0), cfg)
        opt_state = adamw_init(params, opt)
        psa_state = psa_init(params, psa)
        step_fn, refresh_fn, bspecs = make_psa_train_step(
            cfg, mesh, opt, psa, global_batch=4)
        batch = make_lm_batch(cfg, 0, 0, 4, 8)
        with mesh:
            p, o, ps, m = step_fn(params, opt_state, psa_state, batch)
            assert np.isfinite(float(m["loss"])), m
            ps2 = refresh_fn(p, ps, batch)
            p, o, ps2, m2 = step_fn(p, o, ps2, batch)
            assert np.isfinite(float(m2["loss"]))
        # projector leaves stay orthonormal after refresh
        flat = [l for l in jax.tree.leaves(ps2["proj"]) if l is not None]
        assert flat, "no compressible leaves found"
        print("psa step OK", float(m["loss"]), float(m2["loss"]))
    """)


def test_elastic_checkpoint_reshard():
    """Save under a (4,2) mesh, restore onto a (2,4) mesh — elasticity."""
    run_spmd("""
        import tempfile
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
        from repro.checkpoint.manager import CheckpointManager
        devs = np.array(jax.devices())
        mesh1 = Mesh(devs.reshape(4, 2), ("data", "model"))
        mesh2 = Mesh(devs.reshape(2, 4), ("data", "model"))
        tree = {"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8)}
        specs = {"w": P("data", "model")}
        sharded = jax.device_put(tree["w"], NamedSharding(mesh1, specs["w"]))
        with tempfile.TemporaryDirectory() as d:
            mgr = CheckpointManager(d)
            mgr.save(1, {"w": sharded})
            got, step = mgr.restore({"w": sharded}, mesh=mesh2, specs=specs)
        assert step == 1
        np.testing.assert_array_equal(np.asarray(got["w"]),
                                      np.asarray(tree["w"]))
        s = got["w"].sharding
        assert s.mesh.shape["data"] == 2 and s.mesh.shape["model"] == 4
        print("elastic OK")
    """)


def test_sharded_train_step_matches_single_device():
    """pjit-sharded training step == single-device step (same math)."""
    run_spmd("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
        from repro.configs import get_arch, reduced_config
        from repro.models.transformer import init_params
        from repro.models import sharding as shd
        from repro.train.step import loss_fn
        from repro.data.pipeline import make_lm_batch

        cfg = reduced_config(get_arch("h2o-danube-1.8b"))
        params = init_params(jax.random.PRNGKey(0), cfg)
        batch = make_lm_batch(cfg, 0, 0, 4, 8)
        want = float(loss_fn(params, batch, cfg, remat=False))

        devs = np.array(jax.devices()).reshape(4, 2)
        mesh = Mesh(devs, ("data", "model"))
        pspecs = shd.param_specs(params, cfg, mesh)
        ps = jax.device_put(params, shd.named(mesh, pspecs))
        bspecs = shd.batch_specs(cfg, mesh, 4)
        bs = jax.tree.map(
            lambda a, sp: jax.device_put(a, NamedSharding(mesh, sp)),
            batch, bspecs)
        with mesh:
            got = float(jax.jit(
                lambda p, b: loss_fn(p, b, cfg, remat=False))(ps, bs))
        np.testing.assert_allclose(got, want, rtol=1e-4)
        print("sharded==single OK", got, want)
    """)


@pytest.mark.slow
def test_dryrun_production_cell_multipod():
    """One full production-mesh dry-run cell (512 devices) end to end."""
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch",
         "musicgen-medium", "--shape", "decode_32k", "--multipod"],
        capture_output=True, text=True, timeout=900, env=env)
    assert r.returncode == 0, r.stderr[-3000:]
    assert '"status": "ok"' in r.stdout
    assert '"n_devices": 512' in r.stdout
