"""Roofline-term extraction from compiled SPMD artifacts.

``cost_analysis()`` supplies per-device HLO FLOPs and bytes accessed.
Collective traffic is NOT in cost_analysis — we parse the post-partitioner
HLO text and sum the result-shape bytes of every collective op, weighting by
the wire cost of a ring implementation of that collective:

    all-reduce       2 (n-1)/n      (reduce-scatter + all-gather)
    all-gather         (n-1)/n  x n_shards ... == full result x (n-1)/n
    reduce-scatter     (n-1)/n      (of the INPUT size; we see result => x n)
    all-to-all         (n-1)/n
    collective-permute 1            (point-to-point)

Shapes in the compiled module are per-device, so "result bytes" are local
payloads; wire-bytes-per-device is what the ICI roofline needs.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List

__all__ = ["collective_bytes", "roofline_terms", "CollectiveStats",
           "cross_pod_bytes"]

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(bf16|f64|f32|f16|f8e4m3fn|f8e5m2|s64|u64|s32|u32|s16|u16|s8|u8|pred|c64|c128)\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\([^)]*\)|\S+)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute|"
    r"all-reduce-start|all-gather-start|collective-permute-start)\b")
_GROUPS_RE = re.compile(r"replica_groups=\{?([^}]*)\}?")

_WIRE_FACTOR = {
    "all-reduce": lambda n: 2.0 * (n - 1) / max(n, 1),
    "all-gather": lambda n: (n - 1) / max(n, 1),
    "reduce-scatter": lambda n: (n - 1) / max(n, 1),
    "all-to-all": lambda n: (n - 1) / max(n, 1),
    "collective-permute": lambda n: 1.0,
}


def _shape_bytes(type_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.groups()
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str, default: int) -> int:
    """Largest replica group size on the line (the collective's world)."""
    m = _GROUPS_RE.search(line)
    if not m:
        return default
    txt = m.group(1)
    iota = re.search(r"\[(\d+),(\d+)\]", line[m.start():m.start() + 120])
    if "<=[" in line:  # iota format: [groups,size]<=[...]
        m2 = re.search(r"replica_groups=\[(\d+),(\d+)\]<=\[", line)
        if m2:
            return int(m2.group(2))
    sizes = [len([t for t in grp.split(",") if t.strip() != ""])
             for grp in re.findall(r"\{([^{}]*)\}", "{" + txt + "}")]
    return max(sizes) if sizes else default


@dataclasses.dataclass
class CollectiveStats:
    by_kind: Dict[str, float]
    result_bytes: Dict[str, float]
    count: Dict[str, int]

    @property
    def wire_bytes(self) -> float:
        return sum(self.by_kind.values())


def collective_bytes(hlo_text: str, n_devices: int) -> CollectiveStats:
    by_kind: Dict[str, float] = {}
    raw: Dict[str, float] = {}
    count: Dict[str, int] = {}
    for line in hlo_text.splitlines():
        m = _OP_RE.match(line)
        if not m:
            continue
        type_str, op = m.groups()
        op = op.replace("-start", "")
        nbytes = _shape_bytes(type_str)
        world = _group_size(line, n_devices)
        if op == "reduce-scatter":
            nbytes *= world          # result is 1/world of the input payload
        wire = _WIRE_FACTOR[op](world) * nbytes
        by_kind[op] = by_kind.get(op, 0.0) + wire
        raw[op] = raw.get(op, 0.0) + nbytes
        count[op] = count.get(op, 0) + 1
    return CollectiveStats(by_kind, raw, count)


_IOTA_RE = re.compile(
    r"replica_groups=\[(\d+),(\d+)\]<=\[([0-9,]+)\](?:T\(([0-9,]+)\))?")
_PAIRS_RE = re.compile(r"source_target_pairs=\{([^}]*(?:\},\{[^}]*)*)\}")


def _groups_on_line(line: str, n_devices: int):
    """Materialize the replica groups of a collective HLO line (exact for
    both iota and explicit formats)."""
    import numpy as np
    m = _IOTA_RE.search(line)
    if m:
        g, s, dims, perm = m.groups()
        dims = [int(x) for x in dims.split(",")]
        ids = np.arange(int(np.prod(dims))).reshape(dims)
        if perm:
            ids = ids.transpose([int(x) for x in perm.split(",")])
        return ids.reshape(int(g), int(s)).tolist()
    m = _GROUPS_RE.search(line)
    if m:
        groups = [[int(t) for t in grp.split(",") if t.strip()]
                  for grp in re.findall(r"\{([^{}]*)\}", "{" + m.group(1) + "}")]
        groups = [g for g in groups if g]
        if groups:
            return groups
    return [list(range(n_devices))]


def cross_pod_bytes(hlo_text: str, n_devices: int, pod_size: int):
    """Split collective wire bytes into intra-pod vs cross-pod traffic.

    A collective whose replica group spans more than one pod (device //
    pod_size differs within the group) pays the scarce DCI links; this is
    the number the paper's PSA compression is supposed to shrink.
    """
    intra = 0.0
    cross = 0.0
    for line in hlo_text.splitlines():
        m = _OP_RE.match(line)
        if not m:
            continue
        type_str, op = m.groups()
        op = op.replace("-start", "")
        nbytes = _shape_bytes(type_str)
        if op == "collective-permute":
            mp = _PAIRS_RE.search(line)
            is_cross = False
            if mp:
                pairs = re.findall(r"\{(\d+),(\d+)\}", "{" + mp.group(1) + "}")
                is_cross = any(int(a) // pod_size != int(b) // pod_size
                               for a, b in pairs)
            if is_cross:
                cross += nbytes
            else:
                intra += nbytes
            continue
        groups = _groups_on_line(line, n_devices)
        world = max(len(g) for g in groups)
        if op == "reduce-scatter":
            nbytes *= world
        wire = _WIRE_FACTOR[op](world) * nbytes
        spans = any(len({d // pod_size for d in g}) > 1 for g in groups)
        if spans:
            cross += wire
        else:
            intra += wire
    return {"intra_pod_bytes": intra, "cross_pod_bytes": cross}


def roofline_terms(*, flops_per_dev: float, bytes_per_dev: float,
                   wire_bytes_per_dev: float, hw) -> Dict[str, float]:
    """The three per-step time lower bounds (seconds), per device."""
    t_compute = flops_per_dev / hw.PEAK_FLOPS_BF16
    t_memory = bytes_per_dev / hw.HBM_BW
    t_collective = wire_bytes_per_dev / hw.ICI_LINK_BW
    dominant = max(
        ("compute", t_compute), ("memory", t_memory), ("collective", t_collective),
        key=lambda kv: kv[1])[0]
    return {
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_collective,
        "dominant": dominant,
        "bound_s": max(t_compute, t_memory, t_collective),
    }
