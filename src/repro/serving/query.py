"""Batched projection/compression query path for the PSA service.

A served subspace is only useful if something asks it questions.  Queries
here are the two PSA inference primitives: **project** (``y = Q^T x``, the
r-dim compressed code) and **reconstruct** (``Q Q^T x``, the rank-r
approximation).  The path is built for graceful degradation, not peak
throughput:

* **bounded admission queue** — ``submit`` on a full queue returns False
  and counts a shed request; the service never buffers unboundedly while
  a re-solve is hogging the device;
* **per-request deadlines** — every request carries an absolute deadline;
  answers that would arrive late are counted ``expired`` and dropped
  instead of silently served stale-slow;
* **batched execution** — ``process`` drains up to ``max_batch`` requests
  into ONE jitted matmul against the currently served Q (requests never
  see a half-swapped subspace: the Q is read once per batch);
* **p50/p99 accounting** — per-request latency = queue wait + batch
  compute + any chaos-injected delay, observed into an
  ``obs.registry.Histogram`` (O(1) memory; the old keep-every-latency
  list grew with the run). Pass ``registry=`` to expose the same
  histogram/counters through a shared ``MetricsRegistry`` (the service
  dumps it for the ``repro.obs`` CLI).

Chaos integration: ``ChaosHooks.query_delay(req_id)`` returns a *seeded,
per-request* artificial delay.  It is **accounted, never slept** — the
delay is added to the request's latency and can push it past its deadline
(the degradation the bench measures), but wall-clock stays fast and the
outcome for a given (plan seed, req_id) is deterministic across replays
and restarts.
"""
from __future__ import annotations

import dataclasses
import time
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..obs import Histogram

__all__ = ["QueryRequest", "QueryPath"]


@jax.jit
def _project(q: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
    return q.T @ x


@jax.jit
def _reconstruct(q: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
    return q @ (q.T @ x)


@dataclasses.dataclass
class QueryRequest:
    """One admitted query: payload column + its admission bookkeeping."""

    req_id: int
    x: np.ndarray          # (d,) query vector
    submitted_at: float    # wall clock at admission
    deadline: float        # absolute wall clock; late answers expire


class QueryPath:
    """Bounded, deadline-aware, batched query front-end.

    ``capacity`` bounds the admission queue (overflow -> shed).
    ``max_batch`` bounds one ``process`` drain.  ``deadline_s`` is the
    per-request latency budget.  ``mode`` is ``"project"`` or
    ``"reconstruct"``.  ``hooks`` (a ``streaming.chaos.ChaosHooks`` or
    None) supplies seeded per-request injected delays.
    """

    def __init__(self, *, capacity: int = 64, max_batch: int = 16,
                 deadline_s: float = 0.25, mode: str = "project",
                 hooks=None, clock=time.monotonic, registry=None):
        if mode not in ("project", "reconstruct"):
            raise ValueError(f"unknown query mode: {mode}")
        self.capacity = int(capacity)
        self.max_batch = int(max_batch)
        self.deadline_s = float(deadline_s)
        self.mode = mode
        self.hooks = hooks
        self.clock = clock
        self.registry = registry
        self._queue: List[QueryRequest] = []
        self.submitted = 0
        self.answered = 0
        self.shed = 0           # refused at admission (queue full)
        self.expired = 0        # admitted but answer would miss its deadline
        # per-instance histogram unless a shared registry is supplied —
        # two services (or a bench and a test) must not pollute each
        # other's percentiles
        self.latency = (registry.histogram("query_latency_seconds")
                        if registry is not None else Histogram())

    def __len__(self) -> int:
        return len(self._queue)

    def warmup(self, d: int, r: int) -> None:
        """Compile both kernels so first-query latency is not a jit trace."""
        q = jnp.zeros((d, r), jnp.float32)
        x = jnp.zeros((d, 1), jnp.float32)
        _project(q, x).block_until_ready()
        _reconstruct(q, x).block_until_ready()

    def submit(self, req_id: int, x) -> bool:
        """Admit one query; False (and a shed count) when the queue is full."""
        self.submitted += 1
        if self.registry is not None:
            self.registry.counter("query_submitted_total").inc()
        if len(self._queue) >= self.capacity:
            self.shed += 1
            if self.registry is not None:
                self.registry.counter("query_shed_total").inc()
            return False
        now = self.clock()
        self._queue.append(QueryRequest(
            req_id=int(req_id), x=np.asarray(x, np.float32),
            submitted_at=now, deadline=now + self.deadline_s))
        return True

    def process(self, served_q) -> List[Tuple[int, np.ndarray]]:
        """Drain up to ``max_batch`` requests against the served subspace.

        Returns ``[(req_id, answer), ...]`` for the requests that made their
        deadline; late ones are counted ``expired`` and dropped.  Latency is
        accounted as queue wait + batch compute + injected chaos delay — the
        injected part is added to the books, never slept.
        """
        if not self._queue:
            return []
        batch = self._queue[:self.max_batch]
        self._queue = self._queue[self.max_batch:]
        x = jnp.asarray(np.stack([req.x for req in batch], axis=1))
        kernel = _project if self.mode == "project" else _reconstruct
        out = np.asarray(kernel(jnp.asarray(served_q), x))
        done = self.clock()
        answers: List[Tuple[int, np.ndarray]] = []
        for j, req in enumerate(batch):
            injected = (self.hooks.query_delay(req.req_id)
                        if self.hooks is not None else 0.0)
            latency = (done - req.submitted_at) + injected
            if done + injected > req.deadline:
                self.expired += 1
                if self.registry is not None:
                    self.registry.counter("query_expired_total").inc()
                continue
            self.answered += 1
            self.latency.observe(latency)
            answers.append((req.req_id, out[:, j]))
        if self.registry is not None:
            self.registry.counter("query_answered_total").inc(len(answers))
        return answers

    def drain_expired(self) -> int:
        """Expire (without answering) queued requests already past deadline."""
        now = self.clock()
        live = [r for r in self._queue if r.deadline > now]
        n_expired = len(self._queue) - len(live)
        self.expired += n_expired
        self._queue = live
        if n_expired and self.registry is not None:
            self.registry.counter("query_expired_total").inc(n_expired)
        return n_expired

    def summary(self) -> dict:
        """Counters + latency percentiles (seconds) for metrics/bench.

        Percentiles come from the bucketed histogram (rank interpolation,
        clamped to observed min/max) — keys and units unchanged from the
        keep-every-latency implementation this replaced."""
        p50, p99 = self.latency.p50, self.latency.p99
        return {
            "submitted": self.submitted,
            "answered": self.answered,
            "shed": self.shed,
            "expired": self.expired,
            "queued": len(self._queue),
            "p50_s": None if p50 is None else float(p50),
            "p99_s": None if p99 is None else float(p99),
        }
