"""Streaming subsystem: online ingestion, chunked crash-resume, launcher.

The load-bearing assertions are *bitwise*: a run checkpointed and restored
at any chunk boundary must reproduce the uninterrupted fused run's error
trace, final iterate, and comm ledger exactly — including the async
straggler RNG carry. The launcher's merged multi-process sweep must match
the single-process sweep at float32 epsilon (XLA may schedule a width-1
vmap lane-slice differently; everything else is identical arithmetic).
"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager
from repro.core.async_gossip import AsyncConsensus
from repro.core.consensus import DenseConsensus
from repro.core.fdot import fdot
from repro.core.linalg import eigh_topr
from repro.core.metrics import CommLedger
from repro.core.sdot import sdot
from repro.core.sweep import sdot_sweep
from repro.core.topology import erdos_renyi
from repro.data.pipeline import (eigengap_stream, partition_features,
                                 partition_samples)
from repro.streaming.ingest import (CovSketch, FrequentDirections,
                                    StreamingIngestor)
from repro.streaming.launcher import (build_engine, build_schedule,
                                      launch_sweep)
from repro.streaming.resume import RunState, fdot_chunked, sdot_chunked

D, R, N = 14, 3, 6
T_OUTER, T_C, CHUNK = 12, 15, 5


@pytest.fixture(scope="module")
def stream_problem():
    batch_fn, c_pop, q_pop = eigengap_stream(D, R, 0.7, seed=0)
    ing = StreamingIngestor(n_nodes=N, d=D, batch_fn=batch_fn, batch_size=30)
    ing.ingest(20)
    covs = ing.cov_stack()
    _, q_true = eigh_topr(covs.sum(0), R)
    return dict(batch_fn=batch_fn, covs=covs, q_true=q_true,
                graph=erdos_renyi(N, 0.5, seed=1))


# ---------------------------------------------------------------------------
# ingestion
# ---------------------------------------------------------------------------
def test_exact_sketch_matches_batch_pipeline(stream_problem):
    """Streamed covs == partitioning each micro-batch and batching the cov:
    node i's accumulated samples are exactly its per-batch column shards."""
    batch_fn = stream_problem["batch_fn"]
    per_node = [[] for _ in range(N)]
    for t in range(20):
        for i, b in enumerate(partition_samples(batch_fn(t, 30), N)):
            per_node[i].append(b)
    blocks = [jnp.concatenate(bs, axis=1) for bs in per_node]
    want = jnp.stack([b @ b.T / b.shape[1] for b in blocks])
    np.testing.assert_allclose(np.asarray(stream_problem["covs"]),
                               np.asarray(want), rtol=1e-5, atol=1e-6)


def test_ingestor_checkpoint_resume_is_bitwise(tmp_path, stream_problem):
    """Kill-and-restart mid-stream: the stateless stream + checkpointed
    sketch state reproduce the uninterrupted ingestion exactly."""
    batch_fn = stream_problem["batch_fn"]
    full = StreamingIngestor(n_nodes=N, d=D, batch_fn=batch_fn,
                             batch_size=30).ingest(10)

    part = StreamingIngestor(n_nodes=N, d=D, batch_fn=batch_fn,
                             batch_size=30).ingest(4)
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(part.step, part.state())

    fresh = StreamingIngestor(n_nodes=N, d=D, batch_fn=batch_fn,
                              batch_size=30)
    tree, _ = mgr.restore(fresh.state())
    fresh.restore(tree)
    assert fresh.step == 4
    fresh.ingest(6)
    np.testing.assert_array_equal(np.asarray(fresh.cov_stack()),
                                  np.asarray(full.cov_stack()))
    np.testing.assert_array_equal(fresh.samples_per_node,
                                  full.samples_per_node)


def test_frequent_directions_error_bound(stream_problem):
    """||X X^T - B^T B||_2 <= accumulated shrink mass, per node."""
    batch_fn = stream_problem["batch_fn"]
    ell = 10
    fd = StreamingIngestor(n_nodes=N, d=D, batch_fn=batch_fn, batch_size=30,
                           sketch="fd", ell=ell)
    fd.ingest(12)
    exact = StreamingIngestor(n_nodes=N, d=D, batch_fn=batch_fn,
                              batch_size=30).ingest(12)
    sm = np.asarray(exact.sketch.second_moment)
    bb = np.asarray(jnp.einsum("nld,nle->nde", fd.sketch.sketch,
                               fd.sketch.sketch))
    loss = np.asarray(fd.sketch.shrink_loss)
    for i in range(N):
        gap = np.linalg.norm(sm[i] - bb[i], ord=2)
        assert gap <= loss[i] * (1 + 1e-4) + 1e-4
    # and the bound is non-trivial (the sketch actually compresses)
    assert (loss > 0).all()


def test_ingestor_rejects_ragged_batch(stream_problem):
    with pytest.raises(ValueError, match="divide evenly"):
        StreamingIngestor(n_nodes=N, d=D,
                          batch_fn=stream_problem["batch_fn"], batch_size=31)


def test_cov_stack_before_ingest_raises(stream_problem):
    """0/0 must fail at the call site, not emit an all-NaN operand stack."""
    fresh = StreamingIngestor(n_nodes=N, d=D,
                              batch_fn=stream_problem["batch_fn"],
                              batch_size=30)
    with pytest.raises(ValueError, match="ingest"):
        fresh.cov_stack()


def test_fd_rejects_ell_over_d():
    with pytest.raises(ValueError, match="ell"):
        FrequentDirections.init(2, 8, 9)


# ---------------------------------------------------------------------------
# registered pytrees
# ---------------------------------------------------------------------------
def test_ledger_checkpoints_as_pytree(tmp_path):
    """CommLedger round-trips through checkpoint/manager.py with its
    list-valued awake_counts intact (stacking keeps working after restore).
    Counters are float64 at table scale (> 2^24) — restore must not let a
    device_put with x64 disabled downcast them to float32."""
    led = CommLedger(p2p=123456789.0, matrices=10.0, scalars=9.876543219e12)
    led.log_awake_rounds([3, 4, 5])
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, {"ledger": led})
    got, _ = mgr.restore({"ledger": CommLedger()})
    restored = got["ledger"]
    assert restored.p2p == led.p2p
    assert restored.scalars == led.scalars
    assert restored.awake_counts == [3, 4, 5]
    restored.log_awake_rounds([7])            # stacking intact post-restore
    assert restored.awake_counts == [3, 4, 5, 7]
    assert restored.mean_awake() == pytest.approx(np.mean([3, 4, 5, 7]))


def test_runstate_is_pytree():
    st = RunState(q=jnp.zeros((2, 3, 1)), key=jnp.zeros((2,), jnp.uint32),
                  step=jnp.int32(4), errs=jnp.zeros(7),
                  sends=jnp.zeros((7, 2)), counts=jnp.zeros((7, 2)))
    leaves = jax.tree.leaves(st)
    assert len(leaves) == 6
    st2 = jax.tree.map(lambda x: x, st)
    assert isinstance(st2, RunState) and int(st2.step) == 4


# ---------------------------------------------------------------------------
# chunked crash-resume: bit-identical traces, ledgers, iterates
# ---------------------------------------------------------------------------
def _assert_ledgers_equal(a, b):
    assert a.p2p == b.p2p
    assert a.matrices == b.matrices
    assert a.scalars == b.scalars
    assert a.awake_counts == b.awake_counts


def _async_engine():
    return AsyncConsensus(erdos_renyi(N, 0.5, seed=1), p_awake=0.8, seed=5)


@pytest.mark.parametrize("kill_at", [1, 2])
def test_sdot_sync_crash_resume_bitwise(tmp_path, stream_problem, kill_at):
    p = stream_problem
    eng = DenseConsensus(p["graph"])
    mono = sdot(covs=p["covs"], engine=eng, r=R, t_outer=T_OUTER, t_c=T_C,
                q_true=p["q_true"])
    mgr = CheckpointManager(str(tmp_path / f"k{kill_at}"))
    part = sdot_chunked(covs=p["covs"], engine=eng, r=R, t_outer=T_OUTER,
                        t_c=T_C, q_true=p["q_true"], chunk_size=CHUNK,
                        manager=mgr, max_chunks=kill_at)
    assert len(part.error_trace) == min(kill_at * CHUNK, T_OUTER)
    res = sdot_chunked(covs=p["covs"], engine=eng, r=R, t_outer=T_OUTER,
                       t_c=T_C, q_true=p["q_true"], chunk_size=CHUNK,
                       manager=mgr)
    np.testing.assert_array_equal(res.error_trace, mono.error_trace)
    np.testing.assert_array_equal(np.asarray(res.q_nodes),
                                  np.asarray(mono.q_nodes))
    _assert_ledgers_equal(res.ledger, mono.ledger)


@pytest.mark.parametrize("kill_at", [1, 2])
def test_sdot_async_crash_resume_bitwise(tmp_path, stream_problem, kill_at):
    """The straggler path: the RNG key rides in the checkpointed RunState,
    so the restored run continues the SAME awake-mask realization, and the
    realized ledger (sends + awake counts) survives the crash too."""
    p = stream_problem
    mono = sdot(covs=p["covs"], engine=_async_engine(), r=R, t_outer=T_OUTER,
                t_c=T_C, q_true=p["q_true"])
    mgr = CheckpointManager(str(tmp_path / f"k{kill_at}"))
    eng2 = _async_engine()
    sdot_chunked(covs=p["covs"], engine=eng2, r=R, t_outer=T_OUTER, t_c=T_C,
                 q_true=p["q_true"], chunk_size=CHUNK, manager=mgr,
                 max_chunks=kill_at)
    eng3 = _async_engine()
    res = sdot_chunked(covs=p["covs"], engine=eng3, r=R, t_outer=T_OUTER,
                       t_c=T_C, q_true=p["q_true"], chunk_size=CHUNK,
                       manager=mgr)
    np.testing.assert_array_equal(res.error_trace, mono.error_trace)
    np.testing.assert_array_equal(np.asarray(res.q_nodes),
                                  np.asarray(mono.q_nodes))
    _assert_ledgers_equal(res.ledger, mono.ledger)
    # the engine's RNG stream position matches the uninterrupted run's
    eng_mono = _async_engine()
    sdot(covs=p["covs"], engine=eng_mono, r=R, t_outer=T_OUTER, t_c=T_C)
    np.testing.assert_array_equal(np.asarray(eng3._key),
                                  np.asarray(eng_mono._key))


@pytest.mark.parametrize("kill_at", [1, 2])
def test_fdot_crash_resume_bitwise(tmp_path, kill_at):
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.standard_normal((16, 240)), jnp.float32)
    _, q_true = eigh_topr(x @ x.T / x.shape[1], R)
    blocks = partition_features(x, 4)
    eng = DenseConsensus(erdos_renyi(4, 0.9, seed=1))
    mono = fdot(data_blocks=blocks, engine=eng, r=R, t_outer=9, t_c=T_C,
                q_true=q_true)
    mgr = CheckpointManager(str(tmp_path))
    fdot_chunked(data_blocks=blocks, engine=eng, r=R, t_outer=9, t_c=T_C,
                 q_true=q_true, chunk_size=4, manager=mgr, max_chunks=kill_at)
    res = fdot_chunked(data_blocks=blocks, engine=eng, r=R, t_outer=9,
                       t_c=T_C, q_true=q_true, chunk_size=4, manager=mgr)
    np.testing.assert_array_equal(res.error_trace, mono.error_trace)
    np.testing.assert_array_equal(np.asarray(res.q_full),
                                  np.asarray(mono.q_full))
    _assert_ledgers_equal(res.ledger, mono.ledger)


def test_corrupt_latest_checkpoint_recovery(tmp_path, stream_problem):
    """A torn latest snapshot (manifest present, shards unreadable) must not
    kill the run: resume falls back to the newest restorable step and the
    final trace is still bit-identical."""
    p = stream_problem
    eng = DenseConsensus(p["graph"])
    mono = sdot(covs=p["covs"], engine=eng, r=R, t_outer=T_OUTER, t_c=T_C,
                q_true=p["q_true"])
    mgr = CheckpointManager(str(tmp_path), keep_last=5)
    sdot_chunked(covs=p["covs"], engine=eng, r=R, t_outer=T_OUTER, t_c=T_C,
                 q_true=p["q_true"], chunk_size=CHUNK, manager=mgr,
                 max_chunks=2)
    steps = mgr.all_steps()
    assert len(steps) == 2
    # corrupt the newest step's shard file, manifest intact
    shard = os.path.join(tmp_path, f"step_{steps[-1]:08d}", "shards.npz")
    with open(shard, "wb") as f:
        f.write(b"not an npz")
    res = sdot_chunked(covs=p["covs"], engine=eng, r=R, t_outer=T_OUTER,
                       t_c=T_C, q_true=p["q_true"], chunk_size=CHUNK,
                       manager=mgr)
    np.testing.assert_array_equal(res.error_trace, mono.error_trace)
    _assert_ledgers_equal(res.ledger, mono.ledger)


def test_all_checkpoints_corrupt_falls_back_to_fresh(tmp_path,
                                                     stream_problem):
    p = stream_problem
    eng = DenseConsensus(p["graph"])
    mono = sdot(covs=p["covs"], engine=eng, r=R, t_outer=T_OUTER, t_c=T_C,
                q_true=p["q_true"])
    mgr = CheckpointManager(str(tmp_path))
    sdot_chunked(covs=p["covs"], engine=eng, r=R, t_outer=T_OUTER, t_c=T_C,
                 q_true=p["q_true"], chunk_size=CHUNK, manager=mgr,
                 max_chunks=1)
    for s in mgr.all_steps():
        with open(os.path.join(tmp_path, f"step_{s:08d}", "shards.npz"),
                  "wb") as f:
            f.write(b"garbage")
    res = sdot_chunked(covs=p["covs"], engine=eng, r=R, t_outer=T_OUTER,
                       t_c=T_C, q_true=p["q_true"], chunk_size=CHUNK,
                       manager=mgr)
    np.testing.assert_array_equal(res.error_trace, mono.error_trace)


def test_stale_checkpoint_dir_rejected_with_warning(tmp_path,
                                                    stream_problem):
    """A checkpoint dir from a run with a different t_outer must not be
    silently resumed (the buffers have the wrong length): the run warns,
    starts fresh, and still produces the correct full-length trace."""
    p = stream_problem
    eng = DenseConsensus(p["graph"])
    mgr = CheckpointManager(str(tmp_path))
    sdot_chunked(covs=p["covs"], engine=eng, r=R, t_outer=T_OUTER, t_c=T_C,
                 q_true=p["q_true"], chunk_size=CHUNK, manager=mgr,
                 max_chunks=1)
    longer = T_OUTER + 8
    mono = sdot(covs=p["covs"], engine=eng, r=R, t_outer=longer, t_c=T_C,
                q_true=p["q_true"])
    with pytest.warns(UserWarning, match="none restored"):
        res = sdot_chunked(covs=p["covs"], engine=eng, r=R, t_outer=longer,
                           t_c=T_C, q_true=p["q_true"], chunk_size=CHUNK,
                           manager=mgr)
    np.testing.assert_array_equal(res.error_trace, mono.error_trace)


def test_chunk_size_invariance(stream_problem):
    """The trace must not depend on where the chunk boundaries fall."""
    p = stream_problem
    eng = DenseConsensus(p["graph"])
    mono = sdot(covs=p["covs"], engine=eng, r=R, t_outer=T_OUTER, t_c=T_C,
                q_true=p["q_true"])
    for chunk in (1, 4, T_OUTER, T_OUTER + 7):
        res = sdot_chunked(covs=p["covs"], engine=eng, r=R, t_outer=T_OUTER,
                           t_c=T_C, q_true=p["q_true"], chunk_size=chunk)
        np.testing.assert_array_equal(res.error_trace, mono.error_trace)


# ---------------------------------------------------------------------------
# multi-process launcher
# ---------------------------------------------------------------------------
def test_launcher_matches_single_process(tmp_path, stream_problem):
    p = stream_problem
    cases = [{"topology": {"kind": "er", "n": N, "p": 0.5, "seed": 1},
              "schedule": {"kind": "lin2", "cap": T_C}}]
    seeds = [0, 1, 2, 3]
    engines = [build_engine(c["topology"]) for c in cases]
    schedules = [build_schedule(c["schedule"], 8, T_C) for c in cases]
    ref = sdot_sweep(covs=p["covs"], engines=engines, schedules=schedules,
                     r=R, t_outer=8, t_c=T_C, seeds=seeds,
                     q_true=p["q_true"])
    sw = launch_sweep(covs=p["covs"], cases=cases, r=R, t_outer=8, t_c=T_C,
                      seeds=seeds, q_true=p["q_true"],
                      workdir=str(tmp_path), n_workers=2)
    np.testing.assert_allclose(sw.error_traces, ref.error_traces,
                               rtol=1e-6, atol=1e-7)
    np.testing.assert_allclose(np.asarray(sw.q), np.asarray(ref.q),
                               rtol=1e-6, atol=1e-7)
    assert list(sw.seeds) == seeds
    assert sw.ledger.p2p == ref.ledger.p2p
    assert sw.ledger.scalars == ref.ledger.scalars

    # relaunch with published shards: no recompute, same merged result
    sw2 = launch_sweep(covs=p["covs"], cases=cases, r=R, t_outer=8, t_c=T_C,
                       seeds=seeds, q_true=p["q_true"],
                       workdir=str(tmp_path), n_workers=2)
    np.testing.assert_array_equal(sw2.error_traces, sw.error_traces)

    # reusing the workdir with a CHANGED spec must not merge stale shards:
    # the stamped spec fingerprint forces a relaunch
    sw3 = launch_sweep(covs=p["covs"], cases=cases, r=R, t_outer=6, t_c=T_C,
                       seeds=seeds, q_true=p["q_true"],
                       workdir=str(tmp_path), n_workers=2)
    assert sw3.error_traces.shape == (len(seeds), 6)
    np.testing.assert_allclose(sw3.error_traces, ref.error_traces[:, :6],
                               rtol=1e-6, atol=1e-7)


def test_launcher_ragged_shared_covs(tmp_path, stream_problem):
    """Ragged-covs mode with ONE shared stack: stored once in problem.npz,
    zip-broadcast worker-side; merged result matches the single-process
    ragged sweep."""
    p = stream_problem
    cases = [{"topology": {"kind": "er", "n": N, "p": 0.5, "seed": 1}},
             {"topology": {"kind": "ring", "n": N}}]
    seeds = [0, 1]
    engines = [build_engine(c["topology"]) for c in cases]
    ref = sdot_sweep(covs=[p["covs"]], engines=engines, r=R, t_outer=5,
                     t_c=T_C, seeds=seeds, q_true=p["q_true"])
    sw = launch_sweep(covs=[p["covs"]], cases=cases, r=R, t_outer=5,
                      t_c=T_C, seeds=seeds, q_true=p["q_true"],
                      workdir=str(tmp_path), n_workers=2)
    np.testing.assert_allclose(sw.error_traces, ref.error_traces,
                               rtol=1e-6, atol=1e-7)
    np.testing.assert_array_equal(sw.node_counts, ref.node_counts)
    # the shared stack was written once, not once per case
    problem = np.load(os.path.join(tmp_path, "problem.npz"))
    assert "covs_0" in problem and "covs_1" not in problem


def test_launcher_rejects_mismatched_case_covs(tmp_path, stream_problem):
    """A covs list that cannot zip-broadcast with the cases fails up front
    (before any worker spawn), matching sdot_sweep's contract."""
    p = stream_problem
    cases = [{"topology": {"kind": "er", "n": N, "p": 0.5, "seed": 1}}] * 3
    with pytest.raises(ValueError, match="zip-broadcast"):
        launch_sweep(covs=[p["covs"], p["covs"]], cases=cases, r=R,
                     t_outer=4, seeds=[0], workdir=str(tmp_path),
                     n_workers=1)
