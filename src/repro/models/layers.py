"""Shared building blocks: norms, RoPE, embeddings, dense FFN."""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["rms_norm", "rope", "swiglu_ffn", "init_dense", "init_norm", "embed_lookup"]


def init_norm(d: int, dtype) -> jnp.ndarray:
    return jnp.ones((d,), dtype=dtype)


def init_dense(key, d_in: int, d_out: int, dtype, scale: float | None = None):
    scale = (d_in ** -0.5) if scale is None else scale
    return (jax.random.normal(key, (d_in, d_out), dtype=jnp.float32) * scale).astype(dtype)


def rms_norm(x: jnp.ndarray, gamma: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    acc = jnp.float32
    var = jnp.mean(jnp.square(x.astype(acc)), axis=-1, keepdims=True)
    out = x.astype(acc) * jax.lax.rsqrt(var + eps)
    return (out * gamma.astype(acc)).astype(x.dtype)


def rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float = 10_000.0) -> jnp.ndarray:
    """Rotary embedding. x: (b, h, s, hd); positions: (b, s) or (s,)."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    if positions.ndim == 1:
        positions = positions[None]
    angles = positions[:, None, :, None].astype(jnp.float32) * freqs  # (b,1,s,half)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def swiglu_ffn(x: jnp.ndarray, w_gate: jnp.ndarray, w_up: jnp.ndarray,
               w_down: jnp.ndarray) -> jnp.ndarray:
    g = x @ w_gate
    u = x @ w_up
    return (jax.nn.silu(g) * u) @ w_down


def embed_lookup(embed: jnp.ndarray, tokens: jnp.ndarray) -> jnp.ndarray:
    """Token embedding gather; vocab dim may be sharded (SPMD handles it)."""
    return jnp.take(embed, tokens, axis=0)
